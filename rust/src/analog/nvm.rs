//! Emerging non-volatile weight memories (paper Section 3.4).
//!
//! The baseline P2M die stores weights as *fixed transistor widths* (a
//! ROM: zero programmability, perfect retention).  Section 3.4 points out
//! the same heterogeneously-integrated die can instead carry PCM / RRAM /
//! STT-MRAM / FeFET devices, trading programmability against write
//! energy, conductance precision and retention drift.  This module models
//! that trade so the design-space tooling can answer the natural
//! follow-up: *what does making the first layer programmable cost?*
//!
//! Device parameters are representative published values (each constant
//! cites its anchor in comments); the drift/noise models are the standard
//! first-order ones (log-time conductance drift for PCM, cycle-to-cycle
//! lognormal write noise for RRAM).

use crate::util::rng::Rng;

/// Weight-storage technology for the in-pixel weight die.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightTech {
    /// fixed transistor widths (the paper's primary proposal)
    RomWidth,
    /// phase-change memory (mushroom cell)
    Pcm,
    /// filamentary oxide RRAM
    Rram,
    /// spin-transfer-torque MRAM (binary device; multi-bit via banks)
    SttMram,
    /// ferroelectric FET
    Fefet,
}

/// Technology card: programmability cost + imperfection magnitudes.
#[derive(Clone, Copy, Debug)]
pub struct TechParams {
    pub tech: WeightTech,
    /// energy to (re)program one weight level \[J\]
    pub write_energy_j: f64,
    /// write latency per device \[s\]
    pub write_latency_s: f64,
    /// usable conductance levels (analog depth)
    pub levels: u32,
    /// cycle-to-cycle programming noise, sigma as fraction of range
    pub write_noise: f64,
    /// conductance drift exponent nu: G(t) = G0 * (t/t0)^-nu (0 = none)
    pub drift_nu: f64,
    /// write endurance (cycles)
    pub endurance: f64,
}

impl TechParams {
    pub fn for_tech(tech: WeightTech) -> Self {
        match tech {
            // ROM: set at tape-out; "writes" are mask changes.
            WeightTech::RomWidth => TechParams {
                tech,
                write_energy_j: f64::INFINITY,
                write_latency_s: f64::INFINITY,
                levels: 256, // width quantiser resolution (8-bit)
                write_noise: 0.0,
                drift_nu: 0.0,
                endurance: 0.0,
            },
            // PCM: ~10 pJ RESET, ~100 ns, ~16 usable levels, nu ~ 0.05.
            WeightTech::Pcm => TechParams {
                tech,
                write_energy_j: 10e-12,
                write_latency_s: 100e-9,
                levels: 16,
                write_noise: 0.03,
                drift_nu: 0.05,
                endurance: 1e8,
            },
            // RRAM: ~1 pJ, ~50 ns, 8-16 levels, noisy writes.
            WeightTech::Rram => TechParams {
                tech,
                write_energy_j: 1e-12,
                write_latency_s: 50e-9,
                levels: 8,
                write_noise: 0.05,
                drift_nu: 0.005,
                endurance: 1e6,
            },
            // STT-MRAM (22nm embedded, ISSCC'20 ref 27): ~100 fJ, 10 ns,
            // binary; 8 levels via 3-bit banked encoding.
            WeightTech::SttMram => TechParams {
                tech,
                write_energy_j: 0.1e-12,
                write_latency_s: 10e-9,
                levels: 8,
                write_noise: 0.0, // digital banks
                drift_nu: 0.0,
                endurance: 1e12,
            },
            // FeFET: ~1 fJ/switch, fast, ~32 levels, small depolarisation.
            WeightTech::Fefet => TechParams {
                tech,
                write_energy_j: 1e-15,
                write_latency_s: 20e-9,
                levels: 32,
                write_noise: 0.02,
                drift_nu: 0.002,
                endurance: 1e10,
            },
        }
    }

    pub fn is_programmable(&self) -> bool {
        self.write_energy_j.is_finite()
    }

    /// Quantise a normalised weight to this technology's level grid.
    pub fn quantise(&self, w: f64) -> f64 {
        let levels = (self.levels - 1) as f64;
        (w.clamp(0.0, 1.0) * levels).round() / levels
    }

    /// Stored weight after programming noise + drift to time `t_s`
    /// (reference time 1 s).  Deterministic given the rng.
    pub fn stored_weight(&self, w: f64, t_s: f64, rng: &mut Rng) -> f64 {
        let mut g = self.quantise(w);
        if self.write_noise > 0.0 {
            g += rng.normal_ms(0.0, self.write_noise);
        }
        if self.drift_nu > 0.0 && t_s > 1.0 {
            g *= (t_s).powf(-self.drift_nu);
        }
        g.clamp(0.0, 1.0)
    }

    /// Energy to program a whole first-layer bank (P x C signed weights;
    /// each weight is one device — the sign is wiring, not state).
    pub fn reprogram_energy_j(&self, patch_len: usize, channels: usize) -> f64 {
        self.write_energy_j * (patch_len * channels) as f64
    }

    /// Wall time to reprogram the bank through `parallel_writers` lanes.
    pub fn reprogram_time_s(&self, patch_len: usize, channels: usize, parallel_writers: usize) -> f64 {
        let writes = (patch_len * channels).div_ceil(parallel_writers.max(1));
        self.write_latency_s * writes as f64
    }

    /// RMS weight error at time t (quantisation + write noise + drift),
    /// over a uniform weight distribution — the quantity that bounds the
    /// accuracy impact of going programmable.
    pub fn rms_weight_error(&self, t_s: f64, samples: usize, seed: u64) -> f64 {
        let mut rng = Rng::seed(seed);
        let mut sq = 0.0;
        for _ in 0..samples {
            let w = rng.f64();
            let stored = self.stored_weight(w, t_s, &mut rng);
            sq += (stored - w) * (stored - w);
        }
        (sq / samples as f64).sqrt()
    }
}

/// The Section 3.4 comparison table: one row per technology.
pub fn tech_table(patch_len: usize, channels: usize) -> Vec<TechRow> {
    [
        WeightTech::RomWidth,
        WeightTech::Pcm,
        WeightTech::Rram,
        WeightTech::SttMram,
        WeightTech::Fefet,
    ]
    .into_iter()
    .map(|t| {
        let p = TechParams::for_tech(t);
        TechRow {
            tech: t,
            levels: p.levels,
            programmable: p.is_programmable(),
            reprogram_energy_j: p.reprogram_energy_j(patch_len, channels),
            reprogram_time_s: p.reprogram_time_s(patch_len, channels, channels),
            rms_error_1s: p.rms_weight_error(1.0, 4000, 7),
            rms_error_1yr: p.rms_weight_error(3.15e7, 4000, 7),
        }
    })
    .collect()
}

/// One row of the technology comparison.
#[derive(Clone, Copy, Debug)]
pub struct TechRow {
    pub tech: WeightTech,
    pub levels: u32,
    pub programmable: bool,
    pub reprogram_energy_j: f64,
    pub reprogram_time_s: f64,
    pub rms_error_1s: f64,
    pub rms_error_1yr: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn rom_is_perfect_but_frozen() {
        let rom = TechParams::for_tech(WeightTech::RomWidth);
        assert!(!rom.is_programmable());
        let mut rng = Rng::seed(0);
        // 8-bit width quantisation only.
        let e = rom.rms_weight_error(3.15e7, 2000, 1);
        assert!(e < 1.5 / 255.0, "{e}");
        let w = rom.stored_weight(0.5, 1e9, &mut rng);
        assert!((w - rom.quantise(0.5)).abs() < 1e-12);
    }

    #[test]
    fn all_programmable_techs_have_finite_cost() {
        for t in [WeightTech::Pcm, WeightTech::Rram, WeightTech::SttMram, WeightTech::Fefet] {
            let p = TechParams::for_tech(t);
            assert!(p.is_programmable());
            assert!(p.write_energy_j > 0.0 && p.write_energy_j < 1e-9);
            assert!(p.write_latency_s > 0.0);
            assert!(p.levels >= 8);
        }
    }

    #[test]
    fn quantise_respects_levels() {
        Prop::new("nvm quantiser error bounded").run(|rng| {
            let tech = *rng.choose(&[
                WeightTech::Pcm,
                WeightTech::Rram,
                WeightTech::SttMram,
                WeightTech::Fefet,
            ]);
            let p = TechParams::for_tech(tech);
            let w = rng.f64();
            let q = p.quantise(w);
            let lsb = 1.0 / (p.levels - 1) as f64;
            prop_assert!((q - w).abs() <= lsb / 2.0 + 1e-12, "{tech:?} w={w} q={q}");
            Ok(())
        });
    }

    #[test]
    fn pcm_drifts_downward() {
        let pcm = TechParams::for_tech(WeightTech::Pcm);
        let mut rng = Rng::seed(3);
        let fresh = pcm.stored_weight(0.8, 1.0, &mut rng);
        let mut rng = Rng::seed(3);
        let aged = pcm.stored_weight(0.8, 3.15e7, &mut rng);
        assert!(aged < fresh, "PCM must drift down: {aged} vs {fresh}");
    }

    #[test]
    fn drift_hierarchy_matches_physics() {
        // PCM drifts worst, MRAM/ROM not at all.
        let rows = tech_table(75, 8);
        let get = |t: WeightTech| rows.iter().find(|r| r.tech == t).unwrap();
        assert!(get(WeightTech::Pcm).rms_error_1yr > get(WeightTech::Pcm).rms_error_1s);
        assert!(
            (get(WeightTech::SttMram).rms_error_1yr - get(WeightTech::SttMram).rms_error_1s)
                .abs()
                < 1e-12
        );
        assert!(get(WeightTech::Pcm).rms_error_1yr > get(WeightTech::Fefet).rms_error_1yr);
    }

    #[test]
    fn reprogram_costs_scale_with_bank() {
        let p = TechParams::for_tech(WeightTech::Rram);
        let small = p.reprogram_energy_j(75, 8);
        let big = p.reprogram_energy_j(75, 32);
        assert!((big / small - 4.0).abs() < 1e-9);
        // Channel-parallel writers cut wall time c-fold.
        let serial = p.reprogram_time_s(75, 8, 1);
        let par = p.reprogram_time_s(75, 8, 8);
        assert!((serial / par - 8.0).abs() < 0.05);
    }

    #[test]
    fn mram_write_cheapest_per_bank_among_multilevel() {
        let rows = tech_table(75, 8);
        let fefet = rows.iter().find(|r| r.tech == WeightTech::Fefet).unwrap();
        let pcm = rows.iter().find(|r| r.tech == WeightTech::Pcm).unwrap();
        assert!(fefet.reprogram_energy_j < pcm.reprogram_energy_j);
    }

    #[test]
    fn stored_weight_always_in_range() {
        Prop::new("nvm stored weight in [0,1]").run(|rng| {
            let tech = *rng.choose(&[WeightTech::Pcm, WeightTech::Rram, WeightTech::Fefet]);
            let p = TechParams::for_tech(tech);
            let w = rng.range(-0.2, 1.2);
            let t = 10f64.powf(rng.range(0.0, 8.0));
            let stored = p.stored_weight(w, t, rng);
            prop_assert!((0.0..=1.0).contains(&stored), "{stored}");
            Ok(())
        });
    }
}
