//! Behavioural FD-SOI device model — rust twin of `python/compile/device.py`.
//!
//! EKV-style smooth MOSFET current plus a nested-bisection DC solver for
//! the memory-embedded pixel stack:
//!
//! ```text
//! VDD ── source follower (gate = photodiode node M) ── node S
//!     ── weight transistor (gate = select line at VDD) ── column line
//!     ── column load R_col ── GND
//! ```
//!
//! Semantics are kept identical to the python model (same equations, same
//! 60-iteration bisections); the GOLDEN test values below are duplicated
//! verbatim in `python/tests/test_device.py` so the two implementations
//! cannot silently drift.

/// Technology parameters for the 22nm FD-SOI behavioural model
/// (representative low-power-node values, not a foundry PDK — see
/// DESIGN.md §Substitutions).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceParams {
    /// supply voltage \[V\]
    pub vdd: f64,
    /// threshold voltage \[V\]
    pub vth: f64,
    /// subthreshold slope factor
    pub n_slope: f64,
    /// thermal voltage kT/q at 300 K \[V\]
    pub v_t: f64,
    /// channel-length modulation \[1/V\]
    pub lambda_clm: f64,
    /// source-follower current scale per µm width \[A/µm\]
    pub i0_sf: f64,
    /// source-follower width \[µm\]
    pub w_sf: f64,
    /// weight-transistor current scale per µm width \[A/µm\]
    pub i0_w: f64,
    /// minimum weight-transistor width \[µm\]
    pub w_min: f64,
    /// maximum weight-transistor width \[µm\]
    pub w_max: f64,
    /// column-line load resistance \[ohm\]
    pub r_col: f64,
    /// SF gate voltage at zero photocurrent \[V\]
    pub vg_dark: f64,
    /// SF gate voltage at full-scale photocurrent \[V\]
    pub vg_bright: f64,
}

impl Default for DeviceParams {
    fn default() -> Self {
        DeviceParams {
            vdd: 0.8,
            vth: 0.35,
            n_slope: 1.35,
            v_t: 0.02585,
            lambda_clm: 0.08,
            i0_sf: 8.0e-4,
            w_sf: 1.5,
            i0_w: 1.2e-4,
            w_min: 0.04,
            w_max: 0.60,
            r_col: 40.0e3,
            vg_dark: 0.30,
            vg_bright: 0.80,
        }
    }
}

impl DeviceParams {
    /// Load from the `device` object inside `curve_fit.json` (keys match
    /// the python dataclass field names).
    pub fn from_json(v: &crate::util::json::Json) -> Option<Self> {
        let g = |k: &str| v.get(k).and_then(crate::util::json::Json::as_f64);
        Some(DeviceParams {
            vdd: g("vdd")?,
            vth: g("vth")?,
            n_slope: g("n_slope")?,
            v_t: g("v_t")?,
            lambda_clm: g("lambda_clm")?,
            i0_sf: g("i0_sf")?,
            w_sf: g("w_sf")?,
            i0_w: g("i0_w")?,
            w_min: g("w_min")?,
            w_max: g("w_max")?,
            r_col: g("r_col")?,
            vg_dark: g("vg_dark")?,
            vg_bright: g("vg_bright")?,
        })
    }
}

/// EKV interpolation F(x) = ln^2(1 + exp(x/2)): weak inversion
/// (exponential) blending smoothly into strong inversion (square law).
pub fn ekv_f(x: f64) -> f64 {
    let half = x / 2.0;
    // ln(1 + e^(x/2)) ~ x/2 for large x (overflow guard).
    let ln1p = if half > 40.0 { half } else { half.exp().ln_1p() };
    ln1p * ln1p
}

/// Channel current of a width-`width` NMOS (EKV interpolation), smooth in
/// all arguments; 0 at vds <= 0; saturates for large vds.
pub fn drain_current(p: &DeviceParams, i0: f64, width: f64, vgs: f64, vds: f64) -> f64 {
    if width <= 0.0 || vds <= 0.0 {
        return 0.0;
    }
    let nvt = p.n_slope * p.v_t;
    let xf = (vgs - p.vth) / nvt;
    let xr = (vgs - p.vth - p.n_slope * vds) / nvt;
    let i_spec = i0 * width * p.n_slope * p.v_t * p.v_t;
    i_spec * (ekv_f(xf) - ekv_f(xr)) * (1.0 + p.lambda_clm * vds)
}

/// Current through the pixel series stack with the column pinned at
/// `v_out`: solves the internal node S by bisection (SF current decreases
/// in V_S, weight current increases — unique crossing).
fn stack_current(p: &DeviceParams, w_weight: f64, v_g: f64, v_out: f64) -> f64 {
    if w_weight <= 0.0 {
        return 0.0;
    }
    let i_sf = |v_s: f64| drain_current(p, p.i0_sf, p.w_sf, v_g - v_s, p.vdd - v_s);
    let i_w = |v_s: f64| drain_current(p, p.i0_w, w_weight, p.vdd - v_out, v_s - v_out);

    let (mut lo, mut hi) = (v_out, p.vdd);
    if i_sf(lo) - i_w(lo) <= 0.0 {
        // Weight device stronger than the SF can feed: SF-limited stack.
        return i_sf(lo);
    }
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if i_sf(mid) - i_w(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    i_w(0.5 * (lo + hi))
}

/// DC operating point of one memory-embedded pixel.
///
/// * `w_norm`  in [0,1]: normalised weight-transistor width (0 = absent).
/// * `act_norm` in [0,1]: normalised photodiode current (maps linearly to
///   the SF gate voltage in [vg_dark, vg_bright]).
///
/// Returns the column-line output voltage \[V\].
pub fn pixel_output_voltage(p: &DeviceParams, w_norm: f64, act_norm: f64) -> f64 {
    if w_norm <= 0.0 {
        return 0.0;
    }
    let width = p.w_min + w_norm * (p.w_max - p.w_min);
    let v_g = p.vg_dark + act_norm * (p.vg_bright - p.vg_dark);

    let (mut lo, mut hi) = (0.0, p.vdd);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if stack_current(p, width, v_g, mid) - mid / p.r_col > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Sample the (w_norm, act_norm) grid — the SPICE-substitution sweep used
/// for Fig. 3 regeneration and Monte-Carlo refits.
pub fn sample_grid(p: &DeviceParams, n_w: usize, n_a: usize) -> (Vec<f64>, Vec<f64>, Vec<Vec<f64>>) {
    let w_axis: Vec<f64> = (0..n_w).map(|i| i as f64 / (n_w - 1) as f64).collect();
    let a_axis: Vec<f64> = (0..n_a).map(|j| j as f64 / (n_a - 1) as f64).collect();
    let grid = w_axis
        .iter()
        .map(|&w| a_axis.iter().map(|&a| pixel_output_voltage(p, w, a)).collect())
        .collect();
    (w_axis, a_axis, grid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::correlation;

    // (w_norm, act_norm, volts) — mirrored in python/tests/test_device.py.
    const GOLDEN: [(f64, f64, f64); 7] = [
        (0.1, 0.1, 0.005364857384179958),
        (0.25, 0.5, 0.023281322318627215),
        (0.5, 0.25, 0.01891565064634526),
        (0.5, 1.0, 0.04739570775646128),
        (1.0, 0.5, 0.05027962437499446),
        (1.0, 1.0, 0.07599890922177921),
        (0.75, 0.75, 0.058246471631177285),
    ];

    #[test]
    fn golden_values_match_python() {
        let p = DeviceParams::default();
        for &(w, a, v) in &GOLDEN {
            let got = pixel_output_voltage(&p, w, a);
            assert!(
                (got - v).abs() / v < 1e-7,
                "pixel({w},{a}) = {got}, python = {v}"
            );
        }
    }

    #[test]
    fn golden_drain_currents_match_python() {
        let p = DeviceParams::default();
        let a = drain_current(&p, p.i0_sf, 1.0, 0.5, 0.4);
        assert!((a - 3.802059830916563e-06).abs() / a < 1e-9, "{a}");
        let b = drain_current(&p, p.i0_w, 0.3, 0.45, 0.05);
        assert!((b - 5.8820877660453795e-08).abs() / b < 1e-9, "{b}");
    }

    #[test]
    fn ekv_properties() {
        assert!(ekv_f(-200.0) < 1e-30);
        assert!((ekv_f(80.0) - 1600.0).abs() < 1e-3);
        let xs = [-10.0, -1.0, 0.0, 1.0, 5.0, 20.0];
        for w in xs.windows(2) {
            assert!(ekv_f(w[1]) > ekv_f(w[0]));
        }
        assert!(ekv_f(1e4).is_finite());
    }

    #[test]
    fn zero_weight_is_hard_zero() {
        let p = DeviceParams::default();
        assert_eq!(pixel_output_voltage(&p, 0.0, 1.0), 0.0);
        assert_eq!(pixel_output_voltage(&p, 0.0, 0.0), 0.0);
    }

    #[test]
    fn drain_current_edge_cases() {
        let p = DeviceParams::default();
        assert_eq!(drain_current(&p, p.i0_w, 0.0, 0.5, 0.5), 0.0);
        assert_eq!(drain_current(&p, p.i0_w, 0.3, 0.5, 0.0), 0.0);
        assert_eq!(drain_current(&p, p.i0_w, 0.3, 0.5, -0.1), 0.0);
    }

    #[test]
    fn drain_current_linear_in_width() {
        let p = DeviceParams::default();
        let a = drain_current(&p, p.i0_w, 0.2, 0.5, 0.3);
        let b = drain_current(&p, p.i0_w, 0.4, 0.5, 0.3);
        assert!((b - 2.0 * a).abs() / b < 1e-12);
    }

    #[test]
    fn monotone_in_weight_and_activation() {
        let p = DeviceParams::default();
        for &a in &[0.25, 0.5, 1.0] {
            let vs: Vec<f64> =
                [0.1, 0.3, 0.6, 1.0].iter().map(|&w| pixel_output_voltage(&p, w, a)).collect();
            for w in vs.windows(2) {
                assert!(w[1] > w[0], "not monotone in weight at a={a}: {vs:?}");
            }
        }
        for &w in &[0.25, 0.5, 1.0] {
            let vs: Vec<f64> =
                [0.1, 0.3, 0.6, 1.0].iter().map(|&a| pixel_output_voltage(&p, w, a)).collect();
            for v in vs.windows(2) {
                assert!(v[1] > v[0], "not monotone in activation at w={w}: {vs:?}");
            }
        }
    }

    #[test]
    fn bounded_by_supply() {
        let p = DeviceParams::default();
        for &w in &[0.1, 0.5, 1.0] {
            for &a in &[0.0, 0.5, 1.0] {
                let v = pixel_output_voltage(&p, w, a);
                assert!((0.0..p.vdd).contains(&v));
            }
        }
    }

    #[test]
    fn approximately_multiplicative_fig3b() {
        // Correlation of V_out with the ideal product W*A > 0.95 over the
        // grid (the paper's Fig. 3b scatter).
        let p = DeviceParams::default();
        let (w_axis, a_axis, grid) = sample_grid(&p, 9, 9);
        let mut vs = Vec::new();
        let mut prods = Vec::new();
        for (i, &w) in w_axis.iter().enumerate().skip(1) {
            for (j, &a) in a_axis.iter().enumerate() {
                vs.push(grid[i][j]);
                prods.push(w * a);
            }
        }
        let c = correlation(&vs, &prods);
        assert!(c > 0.95, "corr = {c}");
    }

    #[test]
    fn compressive_in_activation() {
        let p = DeviceParams::default();
        let lo = pixel_output_voltage(&p, 1.0, 0.5) - pixel_output_voltage(&p, 1.0, 0.25);
        let hi = pixel_output_voltage(&p, 1.0, 1.0) - pixel_output_voltage(&p, 1.0, 0.75);
        assert!(hi < lo, "surface not compressive: {hi} vs {lo}");
    }

    #[test]
    fn sample_grid_shape() {
        let p = DeviceParams::default();
        let (w, a, g) = sample_grid(&p, 5, 7);
        assert_eq!(w.len(), 5);
        assert_eq!(a.len(), 7);
        assert_eq!(g.len(), 5);
        assert!(g.iter().all(|r| r.len() == 7));
        assert!(g[0].iter().all(|&v| v == 0.0)); // w = 0 row
        assert_eq!((w[0], *w.last().unwrap()), (0.0, 1.0));
    }
}
