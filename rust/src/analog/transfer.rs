//! Pixel transfer surface: the curve-fit polynomial shared with the JAX
//! training path via `artifacts/curve_fit.json`.
//!
//! Two evaluation backends:
//! * [`TransferSurface::Poly`] — the fitted polynomial (what L1/L2 use;
//!   normalised so f(1,1) = 1, exact zero at w = 0);
//! * [`TransferSurface::Device`] — direct DC solution of the device model
//!   (slow; the "SPICE" oracle for validating the fit and for
//!   Monte-Carlo variation studies).

use std::path::Path;

use crate::analog::device::{pixel_output_voltage, DeviceParams};
use crate::util::json::Json;

/// Polynomial degrees: w^1..w^MW (no m = 0 terms — deselected transistor
/// contributes exactly zero), a^0..a^NA.  Must match python `nonideal.py`.
pub const MW: usize = 3;
pub const NA: usize = 3;

/// Fitted polynomial surface + provenance (mirrors python `CurveFit`).
#[derive(Clone, Debug)]
pub struct CurveFit {
    /// `coeffs[m][n]` multiplies w^(m+1) * a^n.
    pub coeffs: [[f64; NA + 1]; MW],
    /// V_out at (w=1, a=1) \[V\] — converts normalised units back to volts.
    pub v_full_scale: f64,
    /// normalised fit residual recorded at fit time.
    pub rmse: f64,
    /// device parameters the fit was generated from.
    pub device: DeviceParams,
}

impl CurveFit {
    /// Normalised transfer f(w, a); exact 0 at w = 0.
    #[inline]
    pub fn eval(&self, w: f64, a: f64) -> f64 {
        let mut acc = 0.0;
        let mut wm = 1.0;
        for m in 0..MW {
            wm *= w;
            let mut an = 1.0;
            for n in 0..=NA {
                acc += self.coeffs[m][n] * wm * an;
                an *= a;
            }
        }
        acc
    }

    /// Parse `curve_fit.json` (schema `p2m-curve-fit-v1`).
    pub fn from_json(v: &Json) -> Result<Self, String> {
        if v.path("schema").and_then(Json::as_str) != Some("p2m-curve-fit-v1") {
            return Err("wrong schema".into());
        }
        if v.path("mw").and_then(Json::as_usize) != Some(MW)
            || v.path("na").and_then(Json::as_usize) != Some(NA)
        {
            return Err("degree mismatch with compiled-in MW/NA".into());
        }
        let rows = v.path("coeffs").and_then(Json::as_arr).ok_or("missing coeffs")?;
        if rows.len() != MW {
            return Err("coeffs row count".into());
        }
        let mut coeffs = [[0.0; NA + 1]; MW];
        for (m, row) in rows.iter().enumerate() {
            let vals = row.as_f64_vec().ok_or("coeff row not numeric")?;
            if vals.len() != NA + 1 {
                return Err("coeff col count".into());
            }
            coeffs[m].copy_from_slice(&vals);
        }
        let device = v
            .path("device")
            .and_then(DeviceParams::from_json)
            .ok_or("missing/invalid device params")?;
        Ok(CurveFit {
            coeffs,
            v_full_scale: v.path("v_full_scale").and_then(Json::as_f64).ok_or("v_full_scale")?,
            rmse: v.path("rmse").and_then(Json::as_f64).ok_or("rmse")?,
            device,
        })
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        let v = Json::parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }
}

/// The pixel transfer surface with selectable backend.
#[derive(Clone, Debug)]
pub enum TransferSurface {
    /// Fitted polynomial, normalised to f(1,1) = 1.
    Poly(CurveFit),
    /// Direct device-model solution, normalised by `v_full_scale`.
    Device { params: DeviceParams, v_full_scale: f64 },
}

impl TransferSurface {
    /// Load the polynomial from `artifacts/curve_fit.json` if built,
    /// otherwise fall back to the (slow, but dependency-free) direct
    /// device backend.
    pub fn load_default() -> Self {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/curve_fit.json");
        match CurveFit::load(&path) {
            Ok(fit) => TransferSurface::Poly(fit),
            Err(_) => Self::device_fallback(),
        }
    }

    pub fn device_fallback() -> Self {
        let params = DeviceParams::default();
        let v_full_scale = pixel_output_voltage(&params, 1.0, 1.0);
        TransferSurface::Device { params, v_full_scale }
    }

    /// Normalised transfer f(w, a) with f(1,1) ~ 1 and f(0, ·) = 0.
    #[inline]
    pub fn eval(&self, w: f64, a: f64) -> f64 {
        match self {
            TransferSurface::Poly(fit) => fit.eval(w, a),
            TransferSurface::Device { params, v_full_scale } => {
                pixel_output_voltage(params, w, a) / v_full_scale
            }
        }
    }

    /// Physical full-scale voltage \[V\] of a single pixel.
    pub fn v_full_scale(&self) -> f64 {
        match self {
            TransferSurface::Poly(fit) => fit.v_full_scale,
            TransferSurface::Device { v_full_scale, .. } => *v_full_scale,
        }
    }

    pub fn device_params(&self) -> DeviceParams {
        match self {
            TransferSurface::Poly(fit) => fit.device,
            TransferSurface::Device { params, .. } => *params,
        }
    }

    pub fn is_poly(&self) -> bool {
        matches!(self, TransferSurface::Poly(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load_poly() -> Option<CurveFit> {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/curve_fit.json");
        CurveFit::load(&path).ok()
    }

    #[test]
    fn poly_zero_at_zero_weight() {
        if let Some(fit) = load_poly() {
            for a in [0.0, 0.3, 0.7, 1.0] {
                assert_eq!(fit.eval(0.0, a), 0.0);
            }
        }
    }

    #[test]
    fn poly_near_one_at_full_scale() {
        if let Some(fit) = load_poly() {
            assert!((fit.eval(1.0, 1.0) - 1.0).abs() < 0.05);
        }
    }

    #[test]
    fn poly_tracks_device_model() {
        // The loaded fit must agree with the in-tree device model: this is
        // the cross-language contract (same JSON drives JAX training).
        let Some(fit) = load_poly() else { return };
        let dev = TransferSurface::Device {
            params: fit.device,
            v_full_scale: fit.v_full_scale,
        };
        for &(w, a) in &[(0.2, 0.4), (0.5, 0.5), (0.8, 0.9), (0.33, 0.77), (1.0, 0.25)] {
            let p = fit.eval(w, a);
            let d = dev.eval(w, a);
            assert!((p - d).abs() < 0.06, "fit({w},{a})={p} device={d}");
        }
    }

    #[test]
    fn device_fallback_normalised() {
        let t = TransferSurface::device_fallback();
        assert!((t.eval(1.0, 1.0) - 1.0).abs() < 1e-9);
        assert_eq!(t.eval(0.0, 0.6), 0.0);
        assert!(t.v_full_scale() > 0.0);
    }

    #[test]
    fn from_json_rejects_bad_schema() {
        let v = Json::parse(r#"{"schema": "nope"}"#).unwrap();
        assert!(CurveFit::from_json(&v).is_err());
    }

    #[test]
    fn from_json_rejects_degree_mismatch() {
        let v = Json::parse(
            r#"{"schema": "p2m-curve-fit-v1", "mw": 2, "na": 3, "coeffs": []}"#,
        )
        .unwrap();
        assert!(CurveFit::from_json(&v).is_err());
    }
}
