//! Weight-to-silicon mapping (paper Sections 3.1 & 4.2).
//!
//! Trained signed weights `theta[p][c]` become *widths of fixed transistors*:
//! positive weights go to transistors wired to the "red" VDD rail, negative
//! magnitudes to the "green" rail, and the two CDS sampling phases
//! subtract their contributions.  Widths are discrete in silicon (the die
//! is a ROM-like structure; the paper quantises to 8-bit weights with
//! < 0.1% accuracy drop), so this module also models the width quantiser.

/// One pixel-embedded weight bank entry: the per-channel width pair.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WidthPair {
    /// normalised width on the positive (red, up-count) rail, in [0, 1]
    pub pos: f64,
    /// normalised width on the negative (green, down-count) rail, in [0, 1]
    pub neg: f64,
}

/// Signed weight -> rail split (clips |theta| at 1: the silicon cannot
/// exceed w_max).  Matches python `model.p2m_stem_weights`.
pub fn split_weight(theta: f64) -> WidthPair {
    WidthPair { pos: theta.clamp(0.0, 1.0), neg: (-theta).clamp(0.0, 1.0) }
}

/// Quantise a normalised width to `bits`-bit discrete levels (uniform mid-
/// tread over [0, 1]; level 0 means "no transistor placed").
pub fn quantise_width(w: f64, bits: u32) -> f64 {
    assert!((1..=24).contains(&bits));
    let levels = ((1u64 << bits) - 1) as f64;
    (w.clamp(0.0, 1.0) * levels).round() / levels
}

/// The full first-layer weight bank: widths[(p, c)] for P pixels-in-patch
/// and C output channels.  This is what gets "manufactured" into the die.
#[derive(Clone, Debug)]
pub struct WeightBank {
    pub patch_len: usize,
    pub channels: usize,
    widths: Vec<WidthPair>,
}

impl WeightBank {
    /// Build from row-major signed weights theta[(p, c)] (length P*C) with
    /// optional width quantisation (`bits` = None keeps float widths).
    pub fn from_theta(theta: &[f32], patch_len: usize, channels: usize, bits: Option<u32>) -> Self {
        assert_eq!(theta.len(), patch_len * channels, "theta shape mismatch");
        let widths = theta
            .iter()
            .map(|&t| {
                let mut wp = split_weight(t as f64);
                if let Some(b) = bits {
                    wp.pos = quantise_width(wp.pos, b);
                    wp.neg = quantise_width(wp.neg, b);
                }
                wp
            })
            .collect();
        WeightBank { patch_len, channels, widths }
    }

    #[inline]
    pub fn get(&self, p: usize, c: usize) -> WidthPair {
        self.widths[p * self.channels + c]
    }

    /// Per-channel column of positive widths (select line for channel c,
    /// red rail high).
    pub fn pos_column(&self, c: usize) -> Vec<f64> {
        (0..self.patch_len).map(|p| self.get(p, c).pos).collect()
    }

    pub fn neg_column(&self, c: usize) -> Vec<f64> {
        (0..self.patch_len).map(|p| self.get(p, c).neg).collect()
    }

    /// Number of weight transistors physically placed (non-zero widths):
    /// the area-proxy the co-design trades against channel count.
    pub fn transistor_count(&self) -> usize {
        self.widths.iter().map(|w| (w.pos > 0.0) as usize + (w.neg > 0.0) as usize).sum()
    }

    /// Transistors per pixel = number of output channels (paper: "there
    /// are as many weight transistors embedded within a pixel as there
    /// are channels in the output feature map") — the *capacity*,
    /// regardless of how many are placed at non-zero width.
    pub fn transistors_per_pixel(&self) -> usize {
        self.channels
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn split_is_exclusive() {
        let w = split_weight(0.7);
        assert_eq!(w, WidthPair { pos: 0.7, neg: 0.0 });
        let w = split_weight(-0.4);
        assert_eq!(w, WidthPair { pos: 0.0, neg: 0.4 });
        let w = split_weight(0.0);
        assert_eq!(w, WidthPair { pos: 0.0, neg: 0.0 });
    }

    #[test]
    fn split_clamps_to_silicon_range() {
        assert_eq!(split_weight(3.0).pos, 1.0);
        assert_eq!(split_weight(-2.5).neg, 1.0);
    }

    #[test]
    fn split_never_both_rails() {
        Prop::new("at most one rail populated").run(|rng| {
            let t = rng.range(-2.0, 2.0);
            let w = split_weight(t);
            prop_assert!(!(w.pos > 0.0 && w.neg > 0.0), "theta={t}");
            prop_assert!(w.pos >= 0.0 && w.neg >= 0.0);
            Ok(())
        });
    }

    #[test]
    fn quantise_endpoints_exact() {
        for bits in [1, 4, 8] {
            assert_eq!(quantise_width(0.0, bits), 0.0);
            assert_eq!(quantise_width(1.0, bits), 1.0);
        }
    }

    #[test]
    fn quantise_error_bounded_by_half_lsb() {
        Prop::new("width quantiser error <= lsb/2").run(|rng| {
            let w = rng.f64();
            let bits = *rng.choose(&[2u32, 4, 8, 12]);
            let q = quantise_width(w, bits);
            let lsb = 1.0 / ((1u64 << bits) - 1) as f64;
            prop_assert!((q - w).abs() <= lsb / 2.0 + 1e-12, "w={w} bits={bits} q={q}");
            Ok(())
        });
    }

    #[test]
    fn quantise_idempotent() {
        Prop::new("width quantiser idempotent").run(|rng| {
            let w = rng.f64();
            let q = quantise_width(w, 8);
            prop_assert!((quantise_width(q, 8) - q).abs() < 1e-15);
            Ok(())
        });
    }

    #[test]
    fn bank_roundtrip_layout() {
        let theta: Vec<f32> = vec![0.5, -0.25, 0.0, 1.0, -1.0, 0.125];
        let bank = WeightBank::from_theta(&theta, 3, 2, None);
        assert_eq!(bank.get(0, 0).pos, 0.5);
        assert_eq!(bank.get(0, 1).neg, 0.25);
        assert_eq!(bank.get(1, 1).pos, 1.0);
        assert_eq!(bank.get(2, 0).neg, 1.0);
        assert_eq!(bank.pos_column(0), vec![0.5, 0.0, 0.0]);
        assert_eq!(bank.neg_column(1), vec![0.25, 0.0, 0.0]);
    }

    #[test]
    fn bank_counts_placed_transistors() {
        let theta: Vec<f32> = vec![0.5, -0.25, 0.0, 1.0];
        let bank = WeightBank::from_theta(&theta, 2, 2, None);
        assert_eq!(bank.transistor_count(), 3);
        assert_eq!(bank.transistors_per_pixel(), 2);
    }

    #[test]
    fn bank_quantisation_applied() {
        let theta: Vec<f32> = vec![0.37; 4];
        let bank = WeightBank::from_theta(&theta, 2, 2, Some(2));
        // 2-bit levels: {0, 1/3, 2/3, 1}; 0.37 -> 1/3
        assert!((bank.get(0, 0).pos - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "theta shape mismatch")]
    fn bank_rejects_bad_shape() {
        WeightBank::from_theta(&[0.0; 5], 2, 2, None);
    }
}
