//! Analytic model metrics: MAdds, parameters, peak memory (Table 2).
//!
//! Peak memory follows the VWW-challenge convention the paper cites
//! (ref. 38): activations are int8 and the peak is the largest single
//! activation tensor alive at once — for MobileNetV2 that is always the
//! widest expansion tensor (e.g. 280x280x96 = 7.53 MB for the 560
//! baseline, which is exactly the paper's Table 2 entry).

use crate::model::arch::{ArchConfig, LayerSpec};

/// Aggregated metrics for one model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelMetrics {
    /// total multiply-accumulates (including any in-pixel layer)
    pub madds: u64,
    /// MAdds executed on the SoC (excludes in-pixel layers)
    pub soc_madds: u64,
    /// parameter count (conv + fc weights)
    pub params: u64,
    /// peak activation memory \[bytes\], int8 convention
    pub peak_memory_bytes: u64,
    /// elements leaving the sensor (first non-in-pixel tensor)
    pub sensor_output_elems: u64,
}

pub fn analyse(cfg: &ArchConfig) -> ModelMetrics {
    analyse_layers(&cfg.layers())
}

pub fn analyse_layers(layers: &[LayerSpec]) -> ModelMetrics {
    let madds: u64 = layers.iter().map(LayerSpec::n_mac).sum();
    let soc_madds: u64 =
        layers.iter().filter(|l| !l.in_pixel).map(LayerSpec::n_mac).sum();
    let params: u64 = layers.iter().map(LayerSpec::n_read).sum();
    // Peak memory counts SoC activation tensors only: an in-pixel layer's
    // input lives in the photodiode array, not RAM (its *output* is the
    // first SoC tensor and is counted via the next layer's input).
    let peak_memory_bytes = layers
        .iter()
        .filter(|l| !l.in_pixel)
        .flat_map(|l| [l.in_elems(), l.out_elems()])
        .max()
        .unwrap_or(0);
    // Sensor output: the input tensor of the first SoC layer.
    let sensor_output_elems = layers
        .iter()
        .find(|l| !l.in_pixel)
        .map(LayerSpec::in_elems)
        .unwrap_or(0);
    ModelMetrics { madds, soc_madds, params, peak_memory_bytes, sensor_output_elems }
}

/// One row of Table 2.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub resolution: usize,
    pub model: &'static str,
    pub madds_g: f64,
    pub peak_memory_mb: f64,
}

/// Regenerate the analytic columns of Table 2 (all three resolutions,
/// both models).  Accuracy columns come from training runs
/// (EXPERIMENTS.md) — they are not analytic.
pub fn table2_rows() -> Vec<Table2Row> {
    let mut rows = Vec::new();
    for &res in &[560usize, 225, 115] {
        for (name, cfg) in [
            ("baseline", ArchConfig::paper_baseline(res)),
            ("p2m_custom", ArchConfig::paper_p2m(res)),
        ] {
            let m = analyse(&cfg);
            rows.push(Table2Row {
                resolution: res,
                model: name,
                madds_g: m.madds as f64 / 1e9,
                peak_memory_mb: m.peak_memory_bytes as f64 / 1e6,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(res: usize, model: &str) -> Table2Row {
        table2_rows()
            .into_iter()
            .find(|r| r.resolution == res && r.model == model)
            .unwrap()
    }

    #[test]
    fn peak_memory_560_baseline_matches_paper() {
        // Paper Table 2: 7.53 MB. The 280x280x96 expansion tensor.
        let r = row(560, "baseline");
        assert!((r.peak_memory_mb - 7.53).abs() < 0.01, "{}", r.peak_memory_mb);
    }

    #[test]
    fn peak_memory_560_p2m_matches_paper() {
        // Paper Table 2: 0.30 MB. The 56x56x96 expansion tensor.
        let r = row(560, "p2m_custom");
        assert!((r.peak_memory_mb - 0.30).abs() < 0.02, "{}", r.peak_memory_mb);
    }

    #[test]
    fn peak_memory_225_matches_paper() {
        // Paper: baseline 1.2 MB, custom 0.049 MB.
        let b = row(225, "baseline");
        assert!((b.peak_memory_mb - 1.2).abs() < 0.1, "{}", b.peak_memory_mb);
        let c = row(225, "p2m_custom");
        assert!((c.peak_memory_mb - 0.049).abs() < 0.01, "{}", c.peak_memory_mb);
    }

    #[test]
    fn peak_memory_115_matches_paper() {
        // Paper: baseline 0.311 MB, custom 0.013 MB.
        let b = row(115, "baseline");
        assert!((b.peak_memory_mb - 0.311).abs() < 0.05, "{}", b.peak_memory_mb);
        let c = row(115, "p2m_custom");
        assert!((c.peak_memory_mb - 0.013).abs() < 0.005, "{}", c.peak_memory_mb);
    }

    #[test]
    fn madds_560_in_paper_ballpark() {
        // Paper: baseline 1.93 G. Our descriptor omits paper-private
        // details (exact width rounding), so allow 20%.
        let b = row(560, "baseline");
        assert!((b.madds_g - 1.93).abs() / 1.93 < 0.2, "{}", b.madds_g);
        // Custom: the paper reports 0.27 G; its text underdetermines where
        // the custom model's first stride-2 lands, and the Table 2 peak-
        // memory entries (which we match *exactly*) pin it to block 1 —
        // which makes the downstream cheaper than 0.27 G.  Assert the
        // direction + a sane floor instead of the unreachable exact value
        // (see EXPERIMENTS.md Table 2 notes).
        let c = row(560, "p2m_custom");
        assert!(c.madds_g < 0.27 + 0.05, "{}", c.madds_g);
        assert!(c.madds_g > 0.02, "{}", c.madds_g);
    }

    #[test]
    fn madds_ratio_reproduces_headline() {
        // Paper Section 5.2 reports ~7.15x MAdds reduction at 560; with
        // the stride placement pinned by the peak-memory entries our
        // custom model reduces *at least* that much.
        let ratio = row(560, "baseline").madds_g / row(560, "p2m_custom").madds_g;
        assert!(ratio >= 7.0, "{ratio}");
    }

    #[test]
    fn memory_ratio_reproduces_headline() {
        // Paper Section 5.2: ~25.1x peak memory reduction at 560.
        let ratio = row(560, "baseline").peak_memory_mb / row(560, "p2m_custom").peak_memory_mb;
        assert!((18.0..32.0).contains(&ratio), "{ratio}");
    }

    #[test]
    fn p2m_sensor_output_is_compressed() {
        let p2m = analyse(&ArchConfig::paper_p2m(560));
        let base = analyse(&ArchConfig::paper_baseline(560));
        assert_eq!(p2m.sensor_output_elems, 112 * 112 * 8);
        assert_eq!(base.sensor_output_elems, 560 * 560 * 3);
    }

    #[test]
    fn soc_madds_excludes_in_pixel_stem() {
        let cfg = ArchConfig::paper_p2m(560);
        let m = analyse(&cfg);
        let stem_macs = cfg.layers()[0].n_mac();
        assert_eq!(m.soc_madds + stem_macs, m.madds);
    }

    #[test]
    fn params_positive_and_plausible() {
        let m = analyse(&ArchConfig::paper_baseline(560));
        // MobileNetV2-ish: between 0.5M and 5M parameters.
        assert!((500_000..5_000_000).contains(&m.params), "{}", m.params);
    }
}
