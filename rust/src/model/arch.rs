//! CNN architecture descriptors: MobileNetV2 with either the standard
//! conv stem (baseline) or the P2M in-pixel stem (paper Section 5.1).
//!
//! These descriptors drive the *analytic* reproductions: MAdds and peak
//! memory (Table 2), the SoC delay model (Eq. 7), and the energy model
//! (Eq. 4-6).  The paper-scale models (560/225/115) are exact functions
//! of the architecture, so no training is needed to regenerate those
//! columns.

/// One convolutional (or fully-connected) layer.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSpec {
    pub name: String,
    /// kernel size (1 for pointwise / fc)
    pub k: usize,
    pub stride: usize,
    /// groups == c_in for depthwise
    pub groups: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub h_in: usize,
    pub w_in: usize,
    pub h_out: usize,
    pub w_out: usize,
    /// executed inside the pixel array (not on the SoC)
    pub in_pixel: bool,
}

impl LayerSpec {
    /// Multiply-accumulates (paper Eq. 5): h_o*w_o*k^2*(c_i/groups)*c_o.
    pub fn n_mac(&self) -> u64 {
        (self.h_out * self.w_out * self.k * self.k * (self.c_in / self.groups) * self.c_out)
            as u64
    }

    /// Parameter reads (paper Eq. 6): k^2*(c_i/groups)*c_o.
    pub fn n_read(&self) -> u64 {
        (self.k * self.k * (self.c_in / self.groups) * self.c_out) as u64
    }

    pub fn in_elems(&self) -> u64 {
        (self.h_in * self.w_in * self.c_in) as u64
    }

    pub fn out_elems(&self) -> u64 {
        (self.h_out * self.w_out * self.c_out) as u64
    }
}

/// Stem variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stem {
    /// P2M in-pixel layer: k x k non-overlapping, c_o channels.
    P2m { k: usize, c_o: usize },
    /// Standard conv stem (k x k, stride s, c_o channels), on the SoC.
    Conv { k: usize, s: usize, c_o: usize },
}

/// Whole-model descriptor.
#[derive(Clone, Debug)]
pub struct ArchConfig {
    pub input: usize,
    pub stem: Stem,
    /// inverted-residual stack: (expansion t, channels c, repeats n, stride s)
    pub blocks: Vec<(usize, usize, usize, usize)>,
    pub head_channels: usize,
    pub num_classes: usize,
}

impl ArchConfig {
    /// The paper's baseline MobileNetV2 (Section 5.1): standard block
    /// stack with a 32-channel stride-2 stem, 320-channel last conv, and
    /// the last depthwise-separable block's channels cut 3x (320 -> 107,
    /// the anti-overfitting tweak).
    pub fn paper_baseline(input: usize) -> Self {
        ArchConfig {
            input,
            stem: Stem::Conv { k: 3, s: 2, c_o: 32 },
            blocks: vec![
                (1, 16, 1, 1),
                (6, 24, 2, 2),
                (6, 32, 3, 2),
                (6, 64, 4, 2),
                (6, 96, 3, 1),
                (6, 160, 3, 2),
                (6, 107, 1, 1), // 320/3: the paper's anti-overfitting cut
            ],
            head_channels: 320,
            num_classes: 2,
        }
    }

    /// The paper's P2M custom model: in-pixel 5x5/5 stem with 8 channels
    /// (Table 1).  The first inverted-residual block takes the stride-2
    /// here (the stem only downsamples 5x vs. the baseline path's 2x+2x),
    /// which is what makes Table 2's peak-memory figures work out: the
    /// widest expansion tensor is 56x56x96 = 0.30 MB at 560 input.
    pub fn paper_p2m(input: usize) -> Self {
        let mut cfg = Self::paper_baseline(input);
        cfg.stem = Stem::P2m { k: 5, c_o: 8 };
        cfg.blocks[0] = (1, 16, 1, 2);
        cfg
    }

    /// The scaled config actually trained in this repo (matches
    /// python `model.ModelConfig` so analytic and measured agree).
    pub fn repo_p2m(input: usize) -> Self {
        ArchConfig {
            input,
            stem: Stem::P2m { k: 5, c_o: 8 },
            blocks: vec![(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 2, 2), (6, 64, 1, 1)],
            head_channels: 128,
            num_classes: 2,
        }
    }

    /// Scaled baseline (python `model.baseline_config`).
    pub fn repo_baseline(input: usize) -> Self {
        ArchConfig {
            input,
            stem: Stem::Conv { k: 3, s: 2, c_o: 32 },
            blocks: vec![
                (1, 16, 1, 1),
                (6, 24, 2, 2),
                (6, 32, 2, 2),
                (6, 64, 2, 2),
                (6, 96, 1, 1),
            ],
            head_channels: 128,
            num_classes: 2,
        }
    }

    /// Expand to per-layer specs.
    pub fn layers(&self) -> Vec<LayerSpec> {
        let mut out = Vec::new();
        let (mut h, mut w);
        let mut c_in;
        match self.stem {
            Stem::P2m { k, c_o } => {
                let ho = self.input / k; // non-overlapping, no padding
                out.push(LayerSpec {
                    name: "stem.p2m".into(),
                    k,
                    stride: k,
                    groups: 1,
                    c_in: 3,
                    c_out: c_o,
                    h_in: self.input,
                    w_in: self.input,
                    h_out: ho,
                    w_out: ho,
                    in_pixel: true,
                });
                h = ho;
                w = ho;
                c_in = c_o;
            }
            Stem::Conv { k, s, c_o } => {
                let ho = self.input.div_ceil(s); // SAME padding
                out.push(LayerSpec {
                    name: "stem.conv".into(),
                    k,
                    stride: s,
                    groups: 1,
                    c_in: 3,
                    c_out: c_o,
                    h_in: self.input,
                    w_in: self.input,
                    h_out: ho,
                    w_out: ho,
                    in_pixel: false,
                });
                h = ho;
                w = ho;
                c_in = c_o;
            }
        }

        for (bi, &(t, c, n, s)) in self.blocks.iter().enumerate() {
            for i in 0..n {
                let stride = if i == 0 { s } else { 1 };
                let c_mid = c_in * t;
                let ho = h.div_ceil(stride);
                if t != 1 {
                    out.push(LayerSpec {
                        name: format!("block{bi}.{i}.expand"),
                        k: 1,
                        stride: 1,
                        groups: 1,
                        c_in,
                        c_out: c_mid,
                        h_in: h,
                        w_in: w,
                        h_out: h,
                        w_out: w,
                        in_pixel: false,
                    });
                }
                out.push(LayerSpec {
                    name: format!("block{bi}.{i}.dw"),
                    k: 3,
                    stride,
                    groups: c_mid,
                    c_in: c_mid,
                    c_out: c_mid,
                    h_in: h,
                    w_in: w,
                    h_out: ho,
                    w_out: ho,
                    in_pixel: false,
                });
                out.push(LayerSpec {
                    name: format!("block{bi}.{i}.project"),
                    k: 1,
                    stride: 1,
                    groups: 1,
                    c_in: c_mid,
                    c_out: c,
                    h_in: ho,
                    w_in: ho,
                    h_out: ho,
                    w_out: ho,
                    in_pixel: false,
                });
                h = ho;
                w = ho;
                c_in = c;
            }
        }

        out.push(LayerSpec {
            name: "head.conv".into(),
            k: 1,
            stride: 1,
            groups: 1,
            c_in,
            c_out: self.head_channels,
            h_in: h,
            w_in: w,
            h_out: h,
            w_out: w,
            in_pixel: false,
        });
        out.push(LayerSpec {
            name: "fc".into(),
            k: 1,
            stride: 1,
            groups: 1,
            c_in: self.head_channels,
            c_out: self.num_classes,
            h_in: 1,
            w_in: 1,
            h_out: 1,
            w_out: 1,
            in_pixel: false,
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2m_stem_dimensions() {
        let layers = ArchConfig::paper_p2m(560).layers();
        let stem = &layers[0];
        assert!(stem.in_pixel);
        assert_eq!((stem.h_out, stem.w_out, stem.c_out), (112, 112, 8));
        assert_eq!(stem.n_mac(), 112 * 112 * 25 * 3 * 8);
    }

    #[test]
    fn baseline_stem_dimensions() {
        let layers = ArchConfig::paper_baseline(560).layers();
        let stem = &layers[0];
        assert!(!stem.in_pixel);
        assert_eq!((stem.h_out, stem.c_out), (280, 32));
    }

    #[test]
    fn depthwise_macs_use_groups() {
        let l = LayerSpec {
            name: "dw".into(),
            k: 3,
            stride: 1,
            groups: 64,
            c_in: 64,
            c_out: 64,
            h_in: 10,
            w_in: 10,
            h_out: 10,
            w_out: 10,
            in_pixel: false,
        };
        assert_eq!(l.n_mac(), 10 * 10 * 9 * 64);
        assert_eq!(l.n_read(), 9 * 64);
    }

    #[test]
    fn layer_chain_is_consistent() {
        for cfg in [
            ArchConfig::paper_baseline(560),
            ArchConfig::paper_p2m(560),
            ArchConfig::repo_p2m(80),
            ArchConfig::repo_baseline(80),
        ] {
            let layers = cfg.layers();
            for pair in layers.windows(2) {
                let (a, b) = (&pair[0], &pair[1]);
                if b.name == "fc" {
                    continue; // pooling intervenes
                }
                assert_eq!(a.c_out, b.c_in, "{} -> {}", a.name, b.name);
                assert_eq!(a.h_out, b.h_in, "{} -> {}", a.name, b.name);
            }
            assert_eq!(layers.last().unwrap().c_out, 2);
        }
    }

    #[test]
    fn repo_matches_python_model_shapes() {
        // python ModelConfig(resolution=80): stem out 16x16x8, blocks
        // [(1,16,1,1),(6,24,2,2),(6,32,2,2),(6,64,1,1)], head 128.
        let layers = ArchConfig::repo_p2m(80).layers();
        assert_eq!(layers[0].h_out, 16);
        let head = layers.iter().find(|l| l.name == "head.conv").unwrap();
        assert_eq!(head.c_out, 128);
        assert_eq!(head.h_in, 4); // 16 -> 16 -> 8 -> 4
    }
}
