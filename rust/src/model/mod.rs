//! Architecture descriptors + analytic metrics (MAdds, peak memory —
//! Table 2; layer specs feeding the Eq. 7 delay model), and the native
//! integer backend executing those layers ([`backend`]).

pub mod analysis;
pub mod arch;
pub mod area;
pub mod backend;
pub mod detect;

pub use analysis::{analyse, analyse_layers, table2_rows, ModelMetrics, Table2Row};
pub use arch::{ArchConfig, LayerSpec, Stem};
pub use area::{AreaModel, Integration};
pub use backend::{NativeBackend, NativeModel};
pub use detect::{Detection, Detector};
