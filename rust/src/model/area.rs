//! Heterogeneous-integration area model (paper Section 3.4, Fig. 5).
//!
//! The weight die sits *under* the backside-illuminated sensor die, so
//! the feasibility question is: do c_o weight transistors (plus select
//! wiring) fit in one pixel's footprint on the chosen logic node?  This
//! module does that accounting for the Fig. 5 stack (Bi-CIS die over
//! weight die, hybrid-bonded) and the two fallbacks the paper names
//! (SPLC, TSV/Fi-CIS).

/// Bonding / integration style (Section 3.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Integration {
    /// die-to-wafer hybrid bond, sub-µm pad pitch (the preferred option)
    HybridBond,
    /// stacked pixel-level connections
    Splc,
    /// through-silicon vias on a front-illuminated sensor
    Tsv,
}

impl Integration {
    /// Interconnect pitch \[µm\] — one vertical connection per column line.
    pub fn pad_pitch_um(self) -> f64 {
        match self {
            Integration::HybridBond => 1.0, // ref 22: sub-µm demonstrated
            Integration::Splc => 2.0,
            Integration::Tsv => 5.0,
        }
    }
}

/// Geometry of the two dies.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// sensor pixel pitch \[µm\] (state-of-the-art CIS: 0.8 - 2.0)
    pub pixel_pitch_um: f64,
    /// logic node's standard-cell transistor footprint [µm^2] including
    /// local wiring (22nm: ~0.1 µm^2; 7nm: ~0.03)
    pub transistor_area_um2: f64,
    /// series rail-select device per weight transistor (the sneak-current
    /// fix in Section 3.3: "splitting each weight transistor into two
    /// series connected transistors")
    pub series_select: bool,
    pub integration: Integration,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            pixel_pitch_um: 1.5,
            transistor_area_um2: 0.1, // 22nm-ish
            series_select: true,
            integration: Integration::HybridBond,
        }
    }
}

impl AreaModel {
    /// Area available under one pixel [µm^2].
    pub fn pixel_area_um2(&self) -> f64 {
        self.pixel_pitch_um * self.pixel_pitch_um
    }

    /// Area needed under one pixel for `channels` weight transistors.
    pub fn weights_area_um2(&self, channels: usize) -> f64 {
        let per_weight = if self.series_select { 2.0 } else { 1.0 };
        // +20% routing overhead for the per-channel select lines.
        channels as f64 * per_weight * self.transistor_area_um2 * 1.2
    }

    /// Does the weight bank fit the pixel footprint?
    pub fn fits(&self, channels: usize) -> bool {
        self.weights_area_um2(channels) <= self.pixel_area_um2()
            && self.integration.pad_pitch_um() <= self.pixel_pitch_um
    }

    /// Max output channels that fit (the area-side bound on c_o —
    /// Section 4.2's "decreasing number of channels ... improv\[es\] area").
    pub fn max_channels(&self) -> usize {
        let mut c = 0usize;
        while self.fits(c + 1) {
            c += 1;
            if c > 4096 {
                break;
            }
        }
        c
    }

    /// Area utilisation [0, 1+] at the paper's design point.
    pub fn utilisation(&self, channels: usize) -> f64 {
        self.weights_area_um2(channels) / self.pixel_area_um2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_design_point_fits() {
        // 8 channels under a 1.5 µm pixel on a 22nm-class weight die.
        let m = AreaModel::default();
        assert!(m.fits(8), "utilisation {}", m.utilisation(8));
        assert!(m.utilisation(8) < 1.0);
    }

    #[test]
    fn thirty_two_channels_do_not_fit_at_22nm() {
        // The baseline model's 32 channels are area-infeasible in-pixel —
        // one of the reasons the co-design cuts c_o to 8.
        let m = AreaModel::default();
        assert!(!m.fits(32), "utilisation {}", m.utilisation(32));
    }

    #[test]
    fn advanced_node_buys_channels() {
        let n22 = AreaModel::default();
        let n7 = AreaModel { transistor_area_um2: 0.03, ..n22 };
        assert!(n7.max_channels() > n22.max_channels());
    }

    #[test]
    fn bigger_pixels_buy_channels() {
        let small = AreaModel { pixel_pitch_um: 1.0, ..AreaModel::default() };
        let large = AreaModel { pixel_pitch_um: 2.5, ..AreaModel::default() };
        assert!(large.max_channels() > small.max_channels());
    }

    #[test]
    fn tsv_pitch_blocks_small_pixels() {
        let m = AreaModel {
            pixel_pitch_um: 1.5,
            integration: Integration::Tsv,
            ..AreaModel::default()
        };
        // 5 µm TSV pitch cannot land one connection per 1.5 µm pixel.
        assert!(!m.fits(4));
        let hb = AreaModel { integration: Integration::HybridBond, ..m };
        assert!(hb.fits(4));
    }

    #[test]
    fn series_select_doubles_area() {
        let with = AreaModel { series_select: true, ..AreaModel::default() };
        let without = AreaModel { series_select: false, ..AreaModel::default() };
        let r = with.weights_area_um2(8) / without.weights_area_um2(8);
        assert!((r - 2.0).abs() < 1e-12);
    }

    #[test]
    fn max_channels_monotone_in_pitch() {
        let mut last = 0;
        for pitch in [0.8, 1.2, 1.6, 2.4] {
            let m = AreaModel { pixel_pitch_um: pitch, ..AreaModel::default() };
            let c = m.max_channels();
            assert!(c >= last);
            last = c;
        }
    }
}
