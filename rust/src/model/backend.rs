//! `NativeBackend` — a deterministic, integer-domain MobileNetV2-style
//! classifier backend that consumes the fleet's quantized ADC codes
//! directly (paper's sensor → SoC split, P2M arXiv:2203.04737; the
//! multi-frame serving pressure on this stage is P2M-DeTrack,
//! arXiv:2205.14285).
//!
//! The P2M stem runs *inside the pixel array*; everything after it —
//! the inverted-residual stack, the head conv, global pooling and the
//! classifier FC — is the digital backend this module executes in pure
//! rust, derived layer-by-layer from the same
//! [`ArchConfig::repo_p2m`] descriptors that drive the analytic
//! MAdds/energy models, so [`NativeModel::macs_per_frame`] agrees
//! exactly with [`crate::energy::PipelineModel::from_arch`]'s SoC MAdd
//! count (pinned by a test below).
//!
//! # Integer domain, dequant-free
//!
//! The wire carries `n_bits`-wide ADC codes
//! ([`crate::sensor::QuantizedFrame`]).  This backend never
//! dequantises: codes are widened to `i32`, normalised onto one 8-bit
//! ladder, and every layer is an exact integer computation —
//!
//! * 1×1 layers (expand / project / head / FC) run through the blocked
//!   integer GEMM [`crate::util::linalg::matmul_i32`] (the input's
//!   row-major `(h·w) × c` layout *is* the GEMM operand, no im2col);
//! * 3×3 depthwise layers use a direct SAME-padded kernel;
//! * global average pooling is an exact `i64` sum with one integer
//!   divide; the FC produces `i64` logits and the argmax (lowest index
//!   wins ties) is the predicted label.
//!
//! After each conv layer the accumulator is requantised back onto the
//! 8-bit activation ladder by a per-layer power-of-two shift with a
//! `clamp(·, 0, 255)` ReLU — all integer, so outputs are bit-exact
//! across platforms, runs, batch groupings and worker counts.  Weights
//! are deterministic synthetic integers in `[-W_MAX, W_MAX]` (seeded
//! from the architecture alone): like
//! [`crate::coordinator::MeanThresholdClassifier`], accuracy is not the
//! point — the point is an honest backend *workload* (the real MAdds of
//! Table 2's custom model) with reproducible outputs, so fleet digests
//! and pool-reassembly invariants can be asserted bit-for-bit.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::pipeline::{BatchClassifier, WirePayload};
use crate::model::arch::{ArchConfig, LayerSpec, Stem};
use crate::sensor::QuantizedFrame;
use crate::util::linalg;
use crate::util::rng::Rng;

/// Synthetic weight magnitude bound (weights are drawn in
/// `[-W_MAX, W_MAX]`); kept small so `K · 255 · W_MAX` accumulations
/// stay far inside `i32` for every layer of the repo architectures.
const W_MAX: i64 = 4;

/// The activation ladder every layer requantises back onto
/// (`0..=CODE_MAX`, i.e. 8-bit unsigned codes).
pub(crate) const CODE_MAX: i32 = 255;

/// The P2M stem kernel/stride (non-overlapping 5×5): a stem output of
/// `h × h` implies a `5h × 5h` sensor.
const STEM_K: usize = 5;

/// One compiled integer backend: the SoC layers of
/// [`ArchConfig::repo_p2m`] for one stem-output shape, with
/// deterministic synthetic weights and per-layer requantisation shifts.
///
/// Immutable and `Arc`-shareable — like the frontend's
/// [`crate::frontend::FramePlan`], one model is compiled per distinct
/// shape and shared by every worker of a backend pool.
pub struct NativeModel {
    /// the architecture this backend realises (stem included, for
    /// reference/analytics)
    pub arch: ArchConfig,
    /// stem-output shape this model consumes (h, w, c)
    pub in_dims: (usize, usize, usize),
    /// SoC layers in execution order (the `in_pixel` stem excluded)
    layers: Vec<LayerSpec>,
    /// per-layer integer weights (layout per op kind, see `forward`)
    weights: Vec<Vec<i32>>,
    /// per-layer right-shift requantising the accumulator back onto the
    /// 8-bit activation ladder (unused for the FC, which emits logits)
    shifts: Vec<u32>,
}

/// Requantisation shift for a layer accumulating `k_eff` products:
/// random ±`W_MAX` weights against ladder-scale activations make the
/// accumulator a zero-mean random walk with σ ≈ √`k_eff`·rms(w)·rms(a),
/// so dividing by ≈ √`k_eff` keeps the post-ReLU codes spread over the
/// `0..=CODE_MAX` ladder layer after layer.  The power-of-two
/// granularity errs toward *mild growth*, which saturates through the
/// deterministic clamp — strictly better than the alternative rounding,
/// which would decay every activation to zero across the 14-layer
/// stack.
fn shift_for(k_eff: usize) -> u32 {
    let target = ((k_eff as f64).sqrt().ceil() as u64).max(2);
    // ceil(log2(target))
    u64::BITS - (target - 1).leading_zeros()
}

/// One accumulator back onto the activation ladder: arithmetic shift,
/// then the ReLU clamp.
#[inline]
fn requant(acc: i32, shift: u32) -> i32 {
    (acc >> shift).clamp(0, CODE_MAX)
}

impl NativeModel {
    /// Compile the backend for a `h × w × c` stem output (`h == w`,
    /// the P2M stem's square non-overlapping geometry): the
    /// [`ArchConfig::repo_p2m`] stack at input resolution `5h`, with
    /// the stem channel count overridden to `c` when it differs from
    /// the descriptor default.  Weights are a pure function of the
    /// architecture (seeded `0xB47E`), mirroring one trained network
    /// deployed across a fleet.
    pub fn for_stem_output(h: usize, w: usize, c: usize) -> Result<Arc<Self>> {
        if h == 0 || w == 0 || c == 0 {
            bail!("native backend: degenerate stem output {h}x{w}x{c}");
        }
        if h != w {
            bail!("native backend: stem output must be square, got {h}x{w}");
        }
        let mut arch = ArchConfig::repo_p2m(h * STEM_K);
        if let Stem::P2m { k, .. } = arch.stem {
            arch.stem = Stem::P2m { k, c_o: c };
        }
        let all = arch.layers();
        let stem = &all[0];
        if !stem.in_pixel || (stem.h_out, stem.w_out, stem.c_out) != (h, w, c) {
            bail!(
                "native backend: arch stem emits {}x{}x{}, payload is {h}x{w}x{c}",
                stem.h_out,
                stem.w_out,
                stem.c_out
            );
        }
        let layers: Vec<LayerSpec> = all.into_iter().filter(|l| !l.in_pixel).collect();

        let mut rng = Rng::seed(0xB47E);
        let mut weights = Vec::with_capacity(layers.len());
        let mut shifts = Vec::with_capacity(layers.len());
        for l in &layers {
            let per_out = l.k * l.k * (l.c_in / l.groups);
            let n_w = per_out * l.c_out;
            weights.push(
                (0..n_w)
                    .map(|_| rng.i64(-W_MAX, W_MAX + 1) as i32)
                    .collect::<Vec<i32>>(),
            );
            shifts.push(shift_for(per_out));
        }
        Ok(Arc::new(NativeModel { arch, in_dims: (h, w, c), layers, weights, shifts }))
    }

    /// SoC multiply-accumulates this backend performs per frame — by
    /// construction identical to the Eq. 5 sum over the architecture's
    /// non-in-pixel layers (the `PipelineModel::from_arch` workload).
    pub fn macs_per_frame(&self) -> u64 {
        self.layers.iter().map(LayerSpec::n_mac).sum()
    }

    /// SoC parameter reads per frame (Eq. 6 over the same layers).
    pub fn reads_per_frame(&self) -> u64 {
        self.layers.iter().map(LayerSpec::n_read).sum()
    }

    /// Number of classes the FC emits.
    pub fn num_classes(&self) -> usize {
        self.arch.num_classes
    }

    /// Run every SoC conv layer up to — but not including — the
    /// classifier FC, leaving the pre-pool feature map in `cur`
    /// (row-major `(h·w) × c`).  Returns the map's grid `(h, w, c)`.
    /// This is the shared trunk of [`NativeModel::logits_into`] and the
    /// detection head ([`crate::model::detect::Detector`]), which reads
    /// the per-cell feature vectors instead of pooling them away.
    pub fn features_into(
        &self,
        codes: &[i32],
        cur: &mut Vec<i32>,
        nxt: &mut Vec<i32>,
    ) -> Result<(usize, usize, usize)> {
        let (h, w, c) = self.in_dims;
        if codes.len() != h * w * c {
            bail!("native backend: {} codes for a {h}x{w}x{c} stem output", codes.len());
        }
        cur.clear();
        cur.extend_from_slice(codes);
        let mut dims = (h, w, c);
        for (li, l) in self.layers.iter().enumerate() {
            let wts = &self.weights[li];
            let shift = self.shifts[li];
            if l.name == "fc" {
                return Ok(dims);
            } else if l.k == 1 && l.groups == 1 {
                // Pointwise (expand / project / head): the row-major
                // (h·w) × c_in activation matrix against the c_in × c_out
                // weight matrix, through the blocked integer GEMM.
                let m = l.h_in * l.w_in;
                nxt.clear();
                nxt.resize(m * l.c_out, 0);
                linalg::matmul_i32(m, l.c_in, l.c_out, cur, wts, nxt);
                for v in nxt.iter_mut() {
                    *v = requant(*v, shift);
                }
            } else if l.groups == l.c_in && l.c_out == l.c_in {
                // Depthwise k×k, SAME padding, per-channel taps.
                depthwise(l, wts, shift, cur, nxt);
            } else {
                bail!("native backend: unsupported layer kind '{}'", l.name);
            }
            dims = (l.h_out, l.w_out, l.c_out);
            std::mem::swap(cur, nxt);
        }
        bail!("native backend: architecture has no fc layer");
    }

    /// Run the integer forward pass over one frame of codes (row-major
    /// `(h, w, c)`, already on the 8-bit ladder) and return the `i64`
    /// logits.  `cur`/`nxt` are caller scratch reused across frames.
    pub fn logits_into(
        &self,
        codes: &[i32],
        cur: &mut Vec<i32>,
        nxt: &mut Vec<i32>,
    ) -> Result<Vec<i64>> {
        let (fh, fw, fc) = self.features_into(codes, cur, nxt)?;
        let fi = self
            .layers
            .iter()
            .position(|l| l.name == "fc")
            .expect("features_into returned, so the fc layer exists");
        let l = &self.layers[fi];
        let wts = &self.weights[fi];
        // Global average pool (exact i64 sum, integer divide)
        // intervenes between the head conv and the FC — find the
        // pooled per-channel codes, then the logits.
        let spatial = fh * fw;
        debug_assert_eq!(fc, l.c_in);
        debug_assert_eq!(cur.len(), spatial * l.c_in);
        let mut pooled = vec![0i32; l.c_in];
        for (ch, p) in pooled.iter_mut().enumerate() {
            let mut sum = 0i64;
            for px in 0..spatial {
                sum += cur[px * l.c_in + ch] as i64;
            }
            *p = (sum / spatial as i64) as i32;
        }
        let mut logits = vec![0i64; l.c_out];
        for (j, logit) in logits.iter_mut().enumerate() {
            let mut acc = 0i64;
            for (ch, &p) in pooled.iter().enumerate() {
                acc += p as i64 * wts[ch * l.c_out + j] as i64;
            }
            *logit = acc;
        }
        Ok(logits)
    }
}

/// Direct SAME-padded depthwise convolution + requantisation:
/// `out[(oy,ox,ch)] = requant(Σ_taps in[...] · w[ch,tap])` with
/// zero-padding chosen so `h_out = ceil(h_in / stride)` (TF-style SAME:
/// the smaller half of the padding leads).
fn depthwise(l: &LayerSpec, wts: &[i32], shift: u32, input: &[i32], out: &mut Vec<i32>) {
    let (h, w, c, k, s) = (l.h_in, l.w_in, l.c_in, l.k, l.stride);
    let (ho, wo) = (l.h_out, l.w_out);
    let pad = |o: usize, i: usize| ((o - 1) * s + k).saturating_sub(i) / 2;
    let (pt, pl) = (pad(ho, h), pad(wo, w));
    out.clear();
    out.resize(ho * wo * c, 0);
    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * c;
            for ky in 0..k {
                let iy = (oy * s + ky) as isize - pt as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * s + kx) as isize - pl as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let in_base = (iy as usize * w + ix as usize) * c;
                    let tap = ky * k + kx;
                    for ch in 0..c {
                        out[base + ch] += input[in_base + ch] * wts[ch * k * k + tap];
                    }
                }
            }
            for ch in 0..c {
                out[base + ch] = requant(out[base + ch], shift);
            }
        }
    }
}

/// The native backend as a serving classifier: per-shape model cache +
/// per-instance scratch, implementing
/// [`crate::coordinator::BatchClassifier`].
///
/// `Send + Clone`, so a [`crate::coordinator::BackendPool`] can hand an
/// instance to every worker thread; classification is per-frame and
/// stateless, so predictions are identical for any batch regrouping and
/// any worker count (pinned by the pool tests).  Models are compiled
/// lazily per distinct stem-output shape — a heterogeneous fleet gets
/// one backend model per sensor design, mirroring the frontend's
/// [`crate::coordinator::PlanBank`].
///
/// Ingest is dequant-free for quantized payloads: codes are widened to
/// `i32` and normalised onto the 8-bit ladder (`<< (8 - bits)` or
/// `>> (bits - 8)`), so e.g. a 4-bit camera and an 8-bit camera land in
/// one activation scale.  Dense f32 payloads (debug/legacy wire) are
/// quantised at ingest through the same deterministic rounding step the
/// wire format uses ([`crate::util::linalg::quantize_codes`], fixed
/// full-scale [`NativeBackend::DENSE_INGEST_HI`]).
#[derive(Clone)]
pub struct NativeBackend {
    models: BTreeMap<(usize, usize, usize), Arc<NativeModel>>,
    codes: Vec<i32>,
    buf_a: Vec<i32>,
    buf_b: Vec<i32>,
}

impl NativeBackend {
    /// Full-scale assumed when quantising a dense f32 payload at ingest:
    /// the P2M receptive-field column full scale (`P = 5·5·3`), the same
    /// ladder the default ADC realises.
    pub const DENSE_INGEST_HI: f64 = 75.0;

    /// Empty backend; models compile lazily on first use per shape.
    pub fn new() -> Self {
        NativeBackend {
            models: BTreeMap::new(),
            codes: Vec::new(),
            buf_a: Vec::new(),
            buf_b: Vec::new(),
        }
    }

    /// The compiled model for a stem-output shape (compiling on first
    /// use).
    pub fn model_for(&mut self, h: usize, w: usize, c: usize) -> Result<Arc<NativeModel>> {
        if let Some(m) = self.models.get(&(h, w, c)) {
            return Ok(m.clone());
        }
        let m = NativeModel::for_stem_output(h, w, c)?;
        self.models.insert((h, w, c), m.clone());
        Ok(m)
    }

    /// Distinct backend models compiled so far.
    pub fn models_compiled(&self) -> usize {
        self.models.len()
    }

    /// Ingest one payload into `self.codes` (8-bit-ladder i32 codes).
    fn ingest(&mut self, payload: &WirePayload) {
        self.codes.clear();
        match payload {
            WirePayload::Quantized(q) => ingest_quantized(q, &mut self.codes),
            WirePayload::Dense(img) => {
                self.codes.resize(img.len(), 0);
                let scale = Self::DENSE_INGEST_HI / CODE_MAX as f64;
                linalg::quantize_codes(&img.data, scale, 0, CODE_MAX as u32, |i, code| {
                    self.codes[i] = code as i32;
                });
            }
            WirePayload::Events(_) => {
                panic!("event payloads must be reassembled onto the dense ladder before classifier ingest")
            }
        }
    }

    /// Integer logits for one wire payload (the classify primitive,
    /// exposed for tests and analysis).
    pub fn logits(&mut self, payload: &WirePayload) -> Result<Vec<i64>> {
        let (h, w, c) = payload.dims();
        let model = self.model_for(h, w, c)?;
        self.ingest(payload);
        // Split the scratch borrows away from `self.codes`.
        let NativeBackend { codes, buf_a, buf_b, .. } = self;
        model.logits_into(codes, buf_a, buf_b)
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Widen a quantized frame's codes to `i32` on the common 8-bit ladder
/// (shared with the detection head's payload ingest).
pub(crate) fn ingest_quantized(q: &QuantizedFrame, out: &mut Vec<i32>) {
    let bits = q.spec.bits;
    out.reserve(q.len());
    for i in 0..q.len() {
        let code = q.code(i) as i32;
        out.push(if bits <= 8 { code << (8 - bits) } else { code >> (bits - 8) });
    }
}

impl BatchClassifier for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn classify(&mut self, batch: &[&WirePayload]) -> Result<Vec<u8>> {
        let mut preds = Vec::with_capacity(batch.len());
        for payload in batch {
            let logits = self.logits(payload)?;
            // Argmax with the lowest index winning ties — deterministic
            // for the all-zero logits a saturated frame can produce.
            let mut best = 0usize;
            for (j, &v) in logits.iter().enumerate() {
                if v > logits[best] {
                    best = j;
                }
            }
            preds.push(best as u8);
        }
        Ok(preds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::{PipelineKind, PipelineModel};
    use crate::sensor::{Image, QuantSpec};

    fn quant_payload(h: usize, w: usize, c: usize, bits: u32, seed: u64) -> WirePayload {
        let spec = QuantSpec::unipolar(75.0, bits);
        let mut q = QuantizedFrame::zeros(h, w, c, spec);
        let mut rng = Rng::seed(seed);
        for i in 0..q.len() {
            let code = rng.usize(0, spec.code_max() as usize + 1) as u32;
            match &mut q.data {
                crate::sensor::QuantData::U8(v) => v[i] = code as u8,
                crate::sensor::QuantData::U16(v) => v[i] = code as u16,
            }
        }
        WirePayload::Quantized(q)
    }

    #[test]
    fn macs_agree_with_the_analytic_pipeline_model() {
        // The backend executes exactly the SoC workload the Eq. 4-7
        // models price: same layer specs, same MAdd/read counts.
        for res in [20usize, 40, 80] {
            let model = NativeModel::for_stem_output(res / 5, res / 5, 8).unwrap();
            let pm = PipelineModel::from_arch(PipelineKind::P2m, &ArchConfig::repo_p2m(res));
            assert_eq!(model.macs_per_frame(), pm.n_mac, "res {res}");
            assert_eq!(model.reads_per_frame(), pm.n_read, "res {res}");
        }
    }

    #[test]
    fn model_shapes_chain_and_end_in_two_classes() {
        let model = NativeModel::for_stem_output(16, 16, 8).unwrap();
        assert_eq!(model.num_classes(), 2);
        assert_eq!(model.in_dims, (16, 16, 8));
        // Degenerate / non-square stem outputs are rejected.
        assert!(NativeModel::for_stem_output(4, 8, 8).is_err());
        assert!(NativeModel::for_stem_output(0, 0, 8).is_err());
    }

    #[test]
    fn logits_are_deterministic_across_instances_and_calls() {
        let payload = quant_payload(4, 4, 8, 8, 3);
        let mut a = NativeBackend::new();
        let mut b = NativeBackend::new();
        let la1 = a.logits(&payload).unwrap();
        let la2 = a.logits(&payload).unwrap();
        let lb = b.logits(&payload).unwrap();
        assert_eq!(la1, la2);
        assert_eq!(la1, lb);
        assert_eq!(la1.len(), 2);
        // Different content must be able to move the logits.
        let other = quant_payload(4, 4, 8, 8, 4);
        assert_ne!(a.logits(&other).unwrap(), la1, "logits blind to input");
    }

    #[test]
    fn sub_byte_codes_normalise_onto_the_8bit_ladder() {
        // A 4-bit frame with code x must ingest exactly like an 8-bit
        // frame with code x << 4: identical logits.
        let spec4 = QuantSpec::unipolar(75.0, 4);
        let spec8 = QuantSpec::unipolar(75.0, 8);
        let mut q4 = QuantizedFrame::zeros(4, 4, 8, spec4);
        let mut q8 = QuantizedFrame::zeros(4, 4, 8, spec8);
        let mut rng = Rng::seed(11);
        for i in 0..q4.len() {
            let code = rng.usize(0, 16) as u8;
            match (&mut q4.data, &mut q8.data) {
                (crate::sensor::QuantData::U8(a), crate::sensor::QuantData::U8(b)) => {
                    a[i] = code;
                    b[i] = code << 4;
                }
                _ => unreachable!(),
            }
        }
        let mut backend = NativeBackend::new();
        assert_eq!(
            backend.logits(&WirePayload::Quantized(q4)).unwrap(),
            backend.logits(&WirePayload::Quantized(q8)).unwrap()
        );
    }

    #[test]
    fn dense_ingest_is_deterministic_and_shape_cached() {
        let img = Image::from_vec(4, 4, 8, (0..128).map(|i| (i % 75) as f32).collect());
        let mut backend = NativeBackend::new();
        let a = backend.logits(&WirePayload::Dense(img.clone())).unwrap();
        let b = backend.logits(&WirePayload::Dense(img)).unwrap();
        assert_eq!(a, b);
        assert_eq!(backend.models_compiled(), 1);
        // A second shape compiles a second model; the first is reused.
        let _ = backend.logits(&quant_payload(8, 8, 8, 8, 1)).unwrap();
        assert_eq!(backend.models_compiled(), 2);
    }

    #[test]
    fn classify_is_per_frame_and_ties_break_low() {
        let payloads: Vec<WirePayload> =
            (0..6).map(|s| quant_payload(4, 4, 8, 8, s)).collect();
        let refs: Vec<&WirePayload> = payloads.iter().collect();
        let mut backend = NativeBackend::new();
        let together = backend.classify(&refs).unwrap();
        assert_eq!(together.len(), 6);
        let single: Vec<u8> = refs
            .iter()
            .map(|p| backend.classify(&[*p]).unwrap()[0])
            .collect();
        assert_eq!(together, single, "batch grouping must not change predictions");
        // All-zero frame -> all-zero activations -> tied logits -> class 0.
        let zero =
            WirePayload::Quantized(QuantizedFrame::zeros(4, 4, 8, QuantSpec::unipolar(75.0, 8)));
        assert_eq!(backend.classify(&[&zero]).unwrap(), vec![0]);
    }

    #[test]
    fn shift_for_is_monotone_and_bounded() {
        assert_eq!(shift_for(9), 2, "3x3 depthwise: ceil(log2(ceil(sqrt(9)))) = 2");
        assert_eq!(shift_for(75), 4, "ceil(sqrt(75)) = 9 -> ceil(log2) = 4");
        let mut last = 0;
        for k in [1usize, 8, 72, 75, 864, 1728] {
            let s = shift_for(k);
            assert!(s >= last, "shift must not shrink with k_eff");
            assert!(s < 16, "shift {s} would zero every activation");
            last = s;
        }
    }
}
