//! The P2M-DeTrack detection head (arXiv:2205.14285): a deterministic
//! integer grid detector over the native backend's *pre-pool* feature
//! maps.
//!
//! Classification pools the final feature map away; detection keeps it.
//! [`Detector`] runs the shared conv trunk
//! ([`NativeModel::features_into`]) and then, per grid cell of the
//! `gh × gw × c` pre-pool map, computes five exact `i64` dot products
//! against synthetic head weights (seeded `0xDE7EC7`, independent of
//! the trunk's `0xB47E` weights):
//!
//! * one **objectness** score — the cell proposes a detection iff its
//!   score is strictly positive;
//! * four **box offsets**, folded onto a small integer canvas
//!   ([`Detector::CELL_UNITS`] units per cell, positive-modulo
//!   reduction) so every box is an exact integer rectangle anchored at
//!   its cell and able to spill into neighbouring cells — which is what
//!   gives the tracker's IoU association something to chew on.
//!
//! Proposals are ranked score-descending with the **lowest cell index
//! winning ties**, the top [`Detector::TOP_K`] survive, and survivors
//! are emitted in raster (cell) order.  Every step is integer
//! arithmetic with total tie-breaks, so for a given payload the
//! detection list is bit-identical across platforms, SIMD tiers, pool
//! sizes and batch groupings — the property the scenario digest pins.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::pipeline::WirePayload;
use crate::model::backend::{ingest_quantized, NativeBackend, NativeModel, CODE_MAX};
use crate::util::linalg;
use crate::util::rng::Rng;

/// Head-weight magnitude bound: weights in `[-H_MAX, H_MAX]`, small so
/// a 1280-channel dot stays far inside `i64`.
const H_MAX: i64 = 3;

/// One detection: an axis-aligned integer box on the frame's cell
/// canvas (`CELL_UNITS` units per grid cell), with its objectness score
/// and originating cell for deterministic ordering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Detection {
    /// raster index of the proposing grid cell (`gy * gw + gx`)
    pub cell: usize,
    /// exact integer objectness score (strictly positive by emission)
    pub score: i64,
    pub x0: i32,
    pub y0: i32,
    /// exclusive right edge (`x1 > x0` always)
    pub x1: i32,
    /// exclusive bottom edge (`y1 > y0` always)
    pub y1: i32,
}

impl Detection {
    /// Box area in canvas units (exact, always positive).
    pub fn area(&self) -> i64 {
        (self.x1 - self.x0) as i64 * (self.y1 - self.y0) as i64
    }
}

/// The per-shape head: the shared conv trunk plus this head's own
/// synthetic weights (one objectness row + four offset rows, each `c`
/// wide for a `c`-channel pre-pool map).
struct DetectHead {
    model: Arc<NativeModel>,
    /// objectness weights (`c` taps)
    w_obj: Vec<i32>,
    /// box-offset weights (4 rows of `c` taps: dx, dy, dw, dh)
    w_box: [Vec<i32>; 4],
}

/// The serving detection head: per-shape model/head cache plus private
/// scratch, mirroring [`NativeBackend`]'s shape-cache idiom.  One
/// `Detector` lives on the consumer thread (detection runs at the
/// per-camera FIFO point, like event reassembly), so no `Clone` needed.
pub struct Detector {
    heads: BTreeMap<(usize, usize, usize), DetectHead>,
    codes: Vec<i32>,
    buf_a: Vec<i32>,
    buf_b: Vec<i32>,
}

impl Detector {
    /// Detections kept per frame after score ranking.
    pub const TOP_K: usize = 4;

    /// Canvas granularity: integer units per grid cell along each axis.
    pub const CELL_UNITS: i32 = 16;

    /// Empty detector; heads compile lazily per stem-output shape.
    pub fn new() -> Self {
        Detector {
            heads: BTreeMap::new(),
            codes: Vec::new(),
            buf_a: Vec::new(),
            buf_b: Vec::new(),
        }
    }

    /// Distinct (trunk, head) pairs compiled so far.
    pub fn heads_compiled(&self) -> usize {
        self.heads.len()
    }

    fn head_for(&mut self, h: usize, w: usize, c: usize) -> Result<&DetectHead> {
        if !self.heads.contains_key(&(h, w, c)) {
            let model = NativeModel::for_stem_output(h, w, c)?;
            // Head channel width = the pre-pool map's channel count: run
            // the trunk once on a zero frame to learn it (cheap, cached).
            let zero = vec![0i32; h * w * c];
            let (_, _, fc) = model.features_into(&zero, &mut self.buf_a, &mut self.buf_b)?;
            let mut rng = Rng::seed(0xDE7E_C7);
            let mut row = || -> Vec<i32> {
                (0..fc).map(|_| rng.i64(-H_MAX, H_MAX + 1) as i32).collect()
            };
            let w_obj = row();
            let w_box = [row(), row(), row(), row()];
            self.heads.insert((h, w, c), DetectHead { model, w_obj, w_box });
        }
        Ok(&self.heads[&(h, w, c)])
    }

    /// Ingest one payload onto the 8-bit i32 ladder (same normalisation
    /// as the classifier backend's ingest).
    fn ingest(codes: &mut Vec<i32>, payload: &WirePayload) {
        codes.clear();
        match payload {
            WirePayload::Quantized(q) => ingest_quantized(q, codes),
            WirePayload::Dense(img) => {
                codes.resize(img.len(), 0);
                let scale = NativeBackend::DENSE_INGEST_HI / CODE_MAX as f64;
                linalg::quantize_codes(&img.data, scale, 0, CODE_MAX as u32, |i, code| {
                    codes[i] = code as i32;
                });
            }
            WirePayload::Events(_) => {
                panic!("event payloads must be reassembled onto the dense ladder before detection")
            }
        }
    }

    /// Detect on one wire payload: clears `out` and fills it with at
    /// most [`Detector::TOP_K`] detections in raster (cell) order.
    pub fn detect(&mut self, payload: &WirePayload, out: &mut Vec<Detection>) -> Result<()> {
        out.clear();
        let (h, w, c) = payload.dims();
        // Borrow-split: lift the scratch buffers out of `self` so the
        // head cache can stay immutably borrowed while they mutate.
        let mut codes = std::mem::take(&mut self.codes);
        let mut buf_a = std::mem::take(&mut self.buf_a);
        let mut buf_b = std::mem::take(&mut self.buf_b);
        Self::ingest(&mut codes, payload);
        self.head_for(h, w, c)?;
        let head = &self.heads[&(h, w, c)];
        let (gh, gw, fc) = head.model.features_into(&codes, &mut buf_a, &mut buf_b)?;
        // The pre-pool map is left in buf_a (row-major (gh·gw) × fc).
        let feat = &buf_a;
        let dot = |cell: usize, wts: &[i32]| -> i64 {
            let base = cell * fc;
            let mut acc = 0i64;
            for ch in 0..fc {
                acc += feat[base + ch] as i64 * wts[ch] as i64;
            }
            acc
        };
        let u = Self::CELL_UNITS as i64;
        let mut candidates: Vec<Detection> = Vec::new();
        for cell in 0..gh * gw {
            let score = dot(cell, &head.w_obj);
            if score <= 0 {
                continue;
            }
            let gy = (cell / gw) as i32;
            let gx = (cell % gw) as i32;
            // Positive-modulo offsets: anchor jitter within the cell,
            // width/height in [CELL_UNITS/4, CELL_UNITS/4 + CELL_UNITS),
            // so boxes overrun into neighbouring cells.
            let dx = dot(cell, &head.w_box[0]).rem_euclid(u) as i32;
            let dy = dot(cell, &head.w_box[1]).rem_euclid(u) as i32;
            let bw = Self::CELL_UNITS / 4 + dot(cell, &head.w_box[2]).rem_euclid(u) as i32;
            let bh = Self::CELL_UNITS / 4 + dot(cell, &head.w_box[3]).rem_euclid(u) as i32;
            let x0 = gx * Self::CELL_UNITS + dx;
            let y0 = gy * Self::CELL_UNITS + dy;
            candidates.push(Detection { cell, score, x0, y0, x1: x0 + bw, y1: y0 + bh });
        }
        // Rank: score descending, lowest cell index breaking ties —
        // then keep TOP_K and restore raster order for emission.
        candidates.sort_by(|a, b| b.score.cmp(&a.score).then(a.cell.cmp(&b.cell)));
        candidates.truncate(Self::TOP_K);
        candidates.sort_by_key(|d| d.cell);
        out.extend_from_slice(&candidates);
        self.codes = codes;
        self.buf_a = buf_a;
        self.buf_b = buf_b;
        Ok(())
    }
}

impl Default for Detector {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sensor::{QuantData, QuantSpec, QuantizedFrame};
    use crate::util::rng::Rng;

    fn quant_payload(h: usize, w: usize, c: usize, seed: u64) -> WirePayload {
        let spec = QuantSpec::unipolar(75.0, 8);
        let mut q = QuantizedFrame::zeros(h, w, c, spec);
        let mut rng = Rng::seed(seed);
        for i in 0..q.len() {
            let code = rng.usize(0, 256) as u32;
            match &mut q.data {
                QuantData::U8(v) => v[i] = code as u8,
                QuantData::U16(v) => v[i] = code as u16,
            }
        }
        WirePayload::Quantized(q)
    }

    #[test]
    fn detections_are_deterministic_ordered_and_bounded() {
        // 40 px camera -> 8x8 stem output -> 2x2 pre-pool grid.
        let payload = quant_payload(8, 8, 8, 3);
        let mut a = Detector::new();
        let mut b = Detector::new();
        let (mut da, mut db, mut da2) = (Vec::new(), Vec::new(), Vec::new());
        a.detect(&payload, &mut da).unwrap();
        b.detect(&payload, &mut db).unwrap();
        a.detect(&payload, &mut da2).unwrap();
        assert_eq!(da, db, "two detectors disagree on one payload");
        assert_eq!(da, da2, "repeat detection drifted");
        assert!(da.len() <= Detector::TOP_K);
        assert_eq!(a.heads_compiled(), 1);
        for pair in da.windows(2) {
            assert!(pair[0].cell < pair[1].cell, "emission must be raster-ordered");
        }
        for d in &da {
            assert!(d.score > 0, "only positive-objectness cells propose");
            assert!(d.x1 > d.x0 && d.y1 > d.y0, "boxes are non-degenerate");
            assert!(d.area() > 0);
        }
        // Different content must be able to move the detections.
        let other = quant_payload(8, 8, 8, 4);
        let mut dother = Vec::new();
        a.detect(&other, &mut dother).unwrap();
        assert_ne!(da, dother, "detections blind to input");
    }

    #[test]
    fn zero_frame_proposes_nothing() {
        // All-zero features -> all dots are 0 -> no strictly-positive
        // objectness -> empty detection list (the deterministic floor).
        let zero = WirePayload::Quantized(QuantizedFrame::zeros(
            8,
            8,
            8,
            QuantSpec::unipolar(75.0, 8),
        ));
        let mut det = Detector::new();
        let mut out = vec![Detection { cell: 0, score: 1, x0: 0, y0: 0, x1: 1, y1: 1 }];
        det.detect(&zero, &mut out).unwrap();
        assert!(out.is_empty(), "detect must clear stale output");
    }
}
