//! Waveform tracing for the SS-ADC / CDS sequence (regenerates Fig. 4).
//!
//! The event-accurate conversion path emits (time, signal, value) samples
//! for the ramp generator output, comparator output, counter enable and
//! counter value — the four traces in the paper's Fig. 4b — plus phase
//! markers for the double-sampling sequence of Fig. 4a.

use std::fmt::Write as _;

/// One recorded sample of a named signal.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// time in seconds from conversion start
    pub t: f64,
    pub signal: &'static str,
    pub value: f64,
}

/// Trace sink with bounded memory (drops samples past `max_samples`).
#[derive(Clone, Debug)]
pub struct WaveformTrace {
    pub samples: Vec<Sample>,
    pub max_samples: usize,
    truncated: bool,
}

impl Default for WaveformTrace {
    fn default() -> Self {
        Self::new(1 << 16)
    }
}

impl WaveformTrace {
    pub fn new(max_samples: usize) -> Self {
        WaveformTrace { samples: Vec::new(), max_samples, truncated: false }
    }

    pub fn record(&mut self, t: f64, signal: &'static str, value: f64) {
        if self.samples.len() < self.max_samples {
            self.samples.push(Sample { t, signal, value });
        } else {
            self.truncated = true;
        }
    }

    pub fn is_truncated(&self) -> bool {
        self.truncated
    }

    /// All samples of one signal in time order.
    pub fn signal(&self, name: &str) -> Vec<&Sample> {
        self.samples.iter().filter(|s| s.signal == name).collect()
    }

    /// Distinct signal names in first-appearance order.
    pub fn signals(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for s in &self.samples {
            if !out.contains(&s.signal) {
                out.push(s.signal);
            }
        }
        out
    }

    /// Last value of a signal at or before time t.
    pub fn value_at(&self, name: &str, t: f64) -> Option<f64> {
        self.samples
            .iter()
            .filter(|s| s.signal == name && s.t <= t)
            .next_back()
            .map(|s| s.value)
    }

    /// CSV dump: `t,signal,value` (Fig. 4 regeneration artifact).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("t_s,signal,value\n");
        for s in &self.samples {
            let _ = writeln!(out, "{:.12e},{},{}", s.t, s.signal, s.value);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_in_order() {
        let mut tr = WaveformTrace::default();
        tr.record(0.0, "ramp", 0.0);
        tr.record(1e-9, "ramp", 0.1);
        tr.record(1e-9, "comp", 1.0);
        assert_eq!(tr.samples.len(), 3);
        assert_eq!(tr.signal("ramp").len(), 2);
        assert_eq!(tr.signals(), vec!["ramp", "comp"]);
    }

    #[test]
    fn value_at_returns_latest() {
        let mut tr = WaveformTrace::default();
        tr.record(0.0, "counter", 0.0);
        tr.record(2e-9, "counter", 5.0);
        tr.record(4e-9, "counter", 9.0);
        assert_eq!(tr.value_at("counter", 3e-9), Some(5.0));
        assert_eq!(tr.value_at("counter", 4e-9), Some(9.0));
        assert_eq!(tr.value_at("counter", -1.0), None);
        assert_eq!(tr.value_at("missing", 1.0), None);
    }

    #[test]
    fn bounded_memory() {
        let mut tr = WaveformTrace::new(2);
        tr.record(0.0, "x", 1.0);
        tr.record(1.0, "x", 2.0);
        tr.record(2.0, "x", 3.0);
        assert_eq!(tr.samples.len(), 2);
        assert!(tr.is_truncated());
    }

    #[test]
    fn csv_format() {
        let mut tr = WaveformTrace::default();
        tr.record(1e-9, "comp", 1.0);
        let csv = tr.to_csv();
        assert!(csv.starts_with("t_s,signal,value\n"));
        assert!(csv.contains(",comp,1"));
    }
}
