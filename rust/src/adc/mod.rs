//! Column-parallel single-slope ADC + digital CDS, re-purposed as the
//! quantised ReLU neuron of the P2M scheme (paper Section 3.3, Fig. 4).

pub mod ss_adc;
pub mod timing;

pub use ss_adc::{CdsConversion, Conversion, SsAdc};
pub use timing::{Sample, WaveformTrace};
