//! Single-slope ADC + digital CDS, re-purposed as the P2M ReLU neuron
//! (paper Section 3.3).
//!
//! Two fidelity levels, both tested against each other:
//!
//! * **functional** — [`SsAdc::quantize`] / [`SsAdc::shifted_relu`]:
//!   arithmetic form `clamp(floor(v/lsb + 0.5), 0, 2^N-1)`, matching the
//!   JAX/Pallas golden model *bit-for-bit* (the ramp is offset by half an
//!   LSB so conversion rounds rather than truncates — a standard mid-rise
//!   quantiser trick);
//! * **event-accurate** — [`SsAdc::convert_event`] / [`SsAdc::convert_cds`]:
//!   walks the counter clock cycle-by-cycle against the ramp, supports
//!   waveform tracing (Fig. 4), comparator offset injection, and the
//!   *true* two-phase CDS sequence (up count on positive-rail sample,
//!   down count on negative-rail sample, counter preset = BN shift).
//!
//! The two differ by design: per-phase counting quantises each sample
//! separately, so event CDS can deviate from the functional combined
//! quantiser by up to ~1.5 LSB — a real circuit non-ideality the paper's
//! co-design absorbs into training.  `frontend::` exposes both modes and
//! the integration tests bound the deviation.

use crate::adc::timing::WaveformTrace;
use crate::config::AdcConfig;

/// Result of one event-accurate conversion.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Conversion {
    /// latched output code
    pub code: u32,
    /// counter clock cycles consumed (always the full ramp: 2^N)
    pub cycles: u64,
}

/// Result of a CDS double conversion (one channel, one receptive field).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CdsConversion {
    /// latched output code after up/down counting + zero clamp (ReLU)
    pub code: u32,
    /// total counter cycles (two ramps)
    pub cycles: u64,
    /// raw signed counter value before the ReLU clamp/saturation
    pub raw: i64,
}

/// Single-slope ADC instance.
#[derive(Clone, Copy, Debug)]
pub struct SsAdc {
    pub cfg: AdcConfig,
}

impl SsAdc {
    pub fn new(cfg: AdcConfig) -> Self {
        SsAdc { cfg }
    }

    /// Functional conversion: `clamp(floor(v/lsb + 0.5), 0, 2^N - 1)`.
    ///
    /// f32 arithmetic to match the JAX golden model exactly.
    #[inline]
    pub fn quantize(&self, v: f64) -> u32 {
        let code = ((v as f32 / self.cfg.lsb() as f32) + 0.5).floor();
        (code.max(0.0) as u32).min(self.cfg.code_max())
    }

    /// Functional shifted-ReLU neuron (paper Fig. 6 step 5): per-channel
    /// ramp scale A (BN gain) and counter preset B (BN shift), then the
    /// quantised ReLU of the CDS difference.
    #[inline]
    pub fn shifted_relu(&self, cds: f64, scale: f64, shift: f64) -> u32 {
        self.quantize(scale * cds + shift)
    }

    /// Dequantise a code back to column-line units.
    #[inline]
    pub fn dequantize(&self, code: u32) -> f64 {
        code as f64 * self.cfg.lsb()
    }

    /// Event-accurate single conversion: the counter runs for the full
    /// 2^N-cycle ramp; the comparator latches the count at the crossing.
    ///
    /// Ramp step k (1-based) compares the input against (k - 0.5) * lsb
    /// (half-LSB offset => rounding, see module docs).  A comparator
    /// offset shifts the effective input.
    pub fn convert_event(&self, v: f64, mut trace: Option<&mut WaveformTrace>) -> Conversion {
        let lsb = self.cfg.lsb();
        let v_eff = v + self.cfg.comparator_offset;
        let t_clk = 1.0 / self.cfg.clock_hz;
        let max = self.cfg.code_max();
        let total_cycles = 1u64 << self.cfg.n_bits;

        // §Perf: without a trace sink the cycle walk below computes
        // exactly `#{k in 1..=max : (k - 0.5) * lsb <= v_eff}` — the
        // closed form is floor(v_eff/lsb + 0.5) clamped.  The unit test
        // `event_matches_functional_everywhere` pins the equivalence;
        // tracing keeps the cycle-accurate walk.
        if trace.is_none() {
            let code = ((v_eff / lsb + 0.5).floor().max(0.0) as u32).min(max);
            return Conversion { code, cycles: total_cycles };
        }

        if let Some(tr) = trace.as_deref_mut() {
            tr.record(0.0, "ramp", 0.0);
            tr.record(0.0, "comp", 1.0); // input above ramp at start
            tr.record(0.0, "counter_en", 1.0);
            tr.record(0.0, "counter", 0.0);
        }

        let mut code = 0u32;
        let mut crossed = false;
        for k in 1..=max {
            let ramp = (k as f64 - 0.5) * lsb;
            let t = k as f64 * t_clk;
            if let Some(tr) = trace.as_deref_mut() {
                tr.record(t, "ramp", ramp);
            }
            if !crossed {
                if ramp <= v_eff {
                    code = k;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.record(t, "counter", k as f64);
                    }
                } else {
                    crossed = true;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.record(t, "comp", 0.0);
                        tr.record(t, "counter_en", 0.0);
                    }
                }
            }
        }
        if !crossed {
            // Saturated: comparator never flipped inside the ramp.
            if let Some(tr) = trace.as_deref_mut() {
                let t_end = total_cycles as f64 * t_clk;
                tr.record(t_end, "comp", 0.0);
                tr.record(t_end, "counter_en", 0.0);
            }
        }
        Conversion { code, cycles: total_cycles }
    }

    /// Event-accurate CDS double sampling (paper Fig. 4a): counter preset
    /// to the BN shift (in counts), up-counts the positive-rail sample,
    /// down-counts the negative-rail sample, then the latch clamps at
    /// zero (ReLU) and saturates at full scale.
    ///
    /// `scale` is realised as a per-channel ramp-slope change: the
    /// effective LSB during both phases is `lsb / scale`.
    pub fn convert_cds(
        &self,
        v_pos: f64,
        v_neg: f64,
        scale: f64,
        shift: f64,
        mut trace: Option<&mut WaveformTrace>,
    ) -> CdsConversion {
        assert!(scale > 0.0, "BN scale must be positive for a ramp slope");
        let scaled = SsAdc {
            cfg: AdcConfig { full_scale: self.cfg.full_scale / scale, ..self.cfg },
        };
        let preset = (shift / self.cfg.lsb()).round() as i64;
        if let Some(tr) = trace.as_deref_mut() {
            tr.record(0.0, "phase", 1.0); // phase 1: red rails high
            tr.record(0.0, "counter_preset", preset as f64);
        }
        let up = scaled.convert_event(v_pos, trace.as_deref_mut());
        if let Some(tr) = trace.as_deref_mut() {
            let t1 = up.cycles as f64 / self.cfg.clock_hz;
            tr.record(t1, "phase", 2.0); // phase 2: green rails high
        }
        let down = scaled.convert_event(v_neg, None);
        let raw = preset + up.code as i64 - down.code as i64;
        let code = raw.clamp(0, self.cfg.code_max() as i64) as u32;
        if let Some(tr) = trace.as_deref_mut() {
            let t_end = (up.cycles + down.cycles) as f64 / self.cfg.clock_hz;
            tr.record(t_end, "latch", code as f64);
        }
        CdsConversion { code, cycles: up.cycles + down.cycles, raw }
    }

    /// Conversion latency of a full CDS double sample \[s\].
    pub fn cds_time_s(&self) -> f64 {
        2.0 * self.cfg.conversion_time_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    fn adc() -> SsAdc {
        SsAdc::new(AdcConfig::default()) // N=8, full_scale=75
    }

    #[test]
    fn quantize_staircase_exact() {
        let a = adc();
        let lsb = a.cfg.lsb();
        assert_eq!(a.quantize(0.0), 0);
        assert_eq!(a.quantize(0.49 * lsb), 0);
        assert_eq!(a.quantize(0.51 * lsb), 1);
        assert_eq!(a.quantize(10.0 * lsb), 10);
        assert_eq!(a.quantize(75.0), 255);
        assert_eq!(a.quantize(1e9), 255);
        assert_eq!(a.quantize(-5.0), 0);
    }

    #[test]
    fn event_matches_functional_everywhere() {
        // The core fidelity contract: cycle-walked conversion == arithmetic.
        let a = adc();
        Prop::new("event == functional").cases(200).run(|rng| {
            let v = rng.range(-10.0, 90.0);
            let ev = a.convert_event(v, None);
            prop_assert!(
                ev.code == a.quantize(v),
                "v={v}: event={} functional={}",
                ev.code,
                a.quantize(v)
            );
            Ok(())
        });
    }

    #[test]
    fn event_consumes_full_ramp() {
        let a = adc();
        assert_eq!(a.convert_event(1.0, None).cycles, 256);
        assert_eq!(a.convert_event(100.0, None).cycles, 256);
    }

    #[test]
    fn conversion_monotone() {
        let a = adc();
        Prop::new("adc monotone").run(|rng| {
            let v1 = rng.range(0.0, 75.0);
            let v2 = v1 + rng.range(0.0, 5.0);
            prop_assert!(a.quantize(v1) <= a.quantize(v2), "v1={v1} v2={v2}");
            Ok(())
        });
    }

    #[test]
    fn comparator_offset_shifts_code() {
        let mut cfg = AdcConfig::default();
        cfg.comparator_offset = 2.0 * cfg.lsb();
        let shifted = SsAdc::new(cfg);
        let base = adc();
        let v = 10.0 * base.cfg.lsb();
        assert_eq!(shifted.convert_event(v, None).code, base.convert_event(v, None).code + 2);
    }

    #[test]
    fn cds_is_up_minus_down_plus_preset() {
        let a = adc();
        let lsb = a.cfg.lsb();
        let r = a.convert_cds(20.0 * lsb, 5.0 * lsb, 1.0, 3.0 * lsb, None);
        assert_eq!(r.raw, 3 + 20 - 5);
        assert_eq!(r.code, 18);
        assert_eq!(r.cycles, 512);
    }

    #[test]
    fn cds_relu_clamps_at_zero() {
        let a = adc();
        let lsb = a.cfg.lsb();
        let r = a.convert_cds(2.0 * lsb, 30.0 * lsb, 1.0, 0.0, None);
        assert!(r.raw < 0);
        assert_eq!(r.code, 0);
    }

    #[test]
    fn cds_saturates_at_full_scale() {
        let a = adc();
        let r = a.convert_cds(74.0, 0.0, 1.0, 40.0, None);
        assert_eq!(r.code, a.cfg.code_max());
    }

    #[test]
    fn cds_scale_changes_ramp_slope() {
        let a = adc();
        let lsb = a.cfg.lsb();
        // scale 2 halves the effective LSB: 10 lsb of input reads ~20 counts.
        let r = a.convert_cds(10.0 * lsb, 0.0, 2.0, 0.0, None);
        assert!((r.code as i64 - 20).unsigned_abs() <= 1, "code={}", r.code);
    }

    #[test]
    fn cds_close_to_functional_combined() {
        // Per-phase counting vs. combined quantisation differ by <= 2
        // codes *inside the conversion window*: the co-design must choose
        // BN gains such that scale * phase-swing <= full_scale (the
        // frontend checks this; outside the window the circuit saturates
        // per phase — see cds_per_phase_saturation_loses_difference).
        let a = adc();
        Prop::new("cds vs functional").cases(150).run(|rng| {
            let scale = rng.range(0.5, 1.2);
            let v_max = a.cfg.full_scale / scale;
            let v_pos = rng.range(0.0, v_max);
            let v_neg = rng.range(0.0, v_max);
            let shift = rng.range(-10.0, 10.0);
            let ev = a.convert_cds(v_pos, v_neg, scale, shift, None);
            let f = a.shifted_relu(v_pos - v_neg, scale, shift);
            let d = (ev.code as i64 - f as i64).unsigned_abs();
            prop_assert!(d <= 2, "event={} functional={f} (pos={v_pos} neg={v_neg})", ev.code);
            Ok(())
        });
    }

    #[test]
    fn cds_per_phase_saturation_loses_difference() {
        // Real circuit limitation: if both phase sums overflow the scaled
        // ramp, their difference is lost (both clamp to full code).  This
        // is why the frontend validates the BN-gain operating window.
        let a = adc();
        let r = a.convert_cds(80.0, 78.0, 2.0, 0.0, None);
        assert_eq!(r.raw, 0, "both phases saturated -> difference lost");
    }

    #[test]
    fn trace_records_fig4_signals() {
        let a = adc();
        let mut tr = WaveformTrace::default();
        let lsb = a.cfg.lsb();
        a.convert_cds(12.0 * lsb, 4.0 * lsb, 1.0, 2.0 * lsb, Some(&mut tr));
        let sigs = tr.signals();
        for s in ["phase", "counter_preset", "ramp", "comp", "counter_en", "counter", "latch"] {
            assert!(sigs.contains(&s), "missing {s} in {sigs:?}");
        }
        // Comparator starts high and ends low.
        let comp = tr.signal("comp");
        assert_eq!(comp.first().unwrap().value, 1.0);
        assert_eq!(comp.last().unwrap().value, 0.0);
        // Latch value equals the conversion result.
        let latched = tr.signal("latch")[0].value as i64;
        assert_eq!(latched, 2 + 12 - 4);
    }

    #[test]
    fn dequantize_roundtrip() {
        let a = adc();
        Prop::new("dequantize within half lsb").run(|rng| {
            let v = rng.range(0.0, 74.0);
            let back = a.dequantize(a.quantize(v));
            prop_assert!((back - v).abs() <= a.cfg.lsb() / 2.0 + 1e-9, "v={v} back={back}");
            Ok(())
        });
    }

    #[test]
    fn timing_matches_paper_2ghz_8bit() {
        // 2^8 cycles at 2 GHz = 128 ns per conversion; CDS = 256 ns.
        let a = adc();
        assert!((a.cds_time_s() - 256e-9).abs() < 1e-15);
    }
}
