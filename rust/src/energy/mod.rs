//! Energy / delay / EDP model (paper Section 5.3, Eq. 4-8, Tables 4-5,
//! Fig. 8) plus the technology-scaling rules behind Table 4's constants.

pub mod constants;
pub mod pipeline;
pub mod scaling;

pub use constants::{DelayConstants, EnergyConstants, PipelineKind};
pub use pipeline::{DelayBreakdown, EnergyBreakdown, PipelineModel};
pub use scaling::{scale_delay, scale_energy, NODES};
