//! CMOS technology scaling (Stillmaker & Baas, Integration 2017).
//!
//! The paper converts its 45nm MAC energy to 22nm "by following standard
//! scaling strategy" (Table 4 note, ref. 44).  This module carries the
//! published per-node energy scaling factors so the conversion is
//! reproducible and auditable rather than a magic constant.
//!
//! Factors are energy-per-operation relative to 90nm, from the
//! Stillmaker-Baas fitted models (general-purpose logic, nominal VDD).

/// Supported nodes \[nm\].
pub const NODES: [u32; 8] = [180, 90, 65, 45, 32, 22, 14, 7];

/// Energy per op relative to the 90nm node (Stillmaker-Baas fitted
/// aggregate; monotone decreasing).
fn rel_energy(node_nm: u32) -> Option<f64> {
    Some(match node_nm {
        180 => 5.09,
        90 => 1.0,
        65 => 0.618,
        45 => 0.345,
        32 => 0.222,
        22 => 0.133,
        14 => 0.0712,
        7 => 0.0316,
        _ => return None,
    })
}

/// Scale an energy measured at `from_nm` to `to_nm`.
pub fn scale_energy(energy_j: f64, from_nm: u32, to_nm: u32) -> Option<f64> {
    Some(energy_j * rel_energy(to_nm)? / rel_energy(from_nm)?)
}

/// Delay scaling: gate delay improves roughly with the node factor; the
/// Stillmaker-Baas delay fit gives these relative per-op delays vs 90nm.
fn rel_delay(node_nm: u32) -> Option<f64> {
    Some(match node_nm {
        180 => 2.40,
        90 => 1.0,
        65 => 0.752,
        45 => 0.571,
        32 => 0.440,
        22 => 0.337,
        14 => 0.259,
        7 => 0.199,
        _ => return None,
    })
}

pub fn scale_delay(delay_s: f64, from_nm: u32, to_nm: u32) -> Option<f64> {
    Some(delay_s * rel_delay(to_nm)? / rel_delay(from_nm)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_scaling() {
        assert_eq!(scale_energy(1e-12, 22, 22), Some(1e-12));
        assert_eq!(scale_delay(1e-9, 45, 45), Some(1e-9));
    }

    #[test]
    fn unknown_node_is_none() {
        assert_eq!(scale_energy(1.0, 22, 10), None);
        assert_eq!(scale_delay(1.0, 28, 22), None);
    }

    #[test]
    fn energy_monotone_decreasing_with_node() {
        for w in NODES.windows(2) {
            let a = scale_energy(1.0, 90, w[0]).unwrap();
            let b = scale_energy(1.0, 90, w[1]).unwrap();
            assert!(b < a, "{} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn roundtrip_is_identity() {
        let e = scale_energy(3.3e-12, 45, 22).unwrap();
        let back = scale_energy(e, 22, 45).unwrap();
        assert!((back - 3.3e-12).abs() < 1e-24);
    }

    #[test]
    fn paper_mac_energy_consistent_with_45nm_origin() {
        // Table 4's e_mac = 1.568 pJ at 22nm, derived from a 45nm value
        // via these rules: the implied 45nm energy must be a plausible
        // published MAC energy (a few pJ).
        let implied_45 = scale_energy(1.568e-12, 22, 45).unwrap();
        assert!(
            (2.0e-12..8.0e-12).contains(&implied_45),
            "implied 45nm MAC energy {implied_45:e}"
        );
    }

    #[test]
    fn delay_scaling_direction() {
        let d22 = scale_delay(10e-9, 65, 22).unwrap();
        assert!(d22 < 10e-9);
        let d180 = scale_delay(10e-9, 65, 180).unwrap();
        assert!(d180 > 10e-9);
    }
}
