//! Published per-component energy/delay constants (paper Tables 4 & 5).
//!
//! These are the paper's own measured/derived values for 22nm CMOS; the
//! EDP results of Section 5.3 are a model evaluated from them, so reusing
//! them *is* the reproduction (DESIGN.md §Substitutions).  The MAC energy
//! was scaled 45nm -> 22nm by the authors with the Stillmaker-Baas rules
//! re-implemented in `energy::scaling` (cross-checked there).

/// Pipeline flavour of Table 4's rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineKind {
    /// P2M: in-pixel first layer, compressed sensor output.
    P2m,
    /// Baseline (C): compressed MobileNetV2 (aggressive stem downsample),
    /// raw pixels leave the sensor.
    BaselineCompressed,
    /// Baseline (NC): standard first-layer downsampling.
    BaselineNonCompressed,
}

/// Table 4: per-operation energies \[J\].
#[derive(Clone, Copy, Debug)]
pub struct EnergyConstants {
    /// per-pixel sensing (read-out) energy, P2M pixels \[J\]
    pub e_pix_p2m: f64,
    /// per-pixel sensing energy, standard pixels \[J\]
    pub e_pix_baseline: f64,
    /// per-value ADC energy, P2M (8-bit SS-ADC re-purposed) \[J\]
    pub e_adc_p2m: f64,
    /// per-value ADC energy, baseline compressed \[J\]
    pub e_adc_baseline_c: f64,
    /// per-value ADC energy, baseline non-compressed \[J\]
    pub e_adc_baseline_nc: f64,
    /// sensor-to-SoC communication per value \[J\]
    pub e_com: f64,
    /// one MAC on the SoC, 22nm \[J\]
    pub e_mac: f64,
    /// one 32-bit parameter read \[J\] (paper ignores it: < 1e-4 of total)
    pub e_read: f64,
}

impl Default for EnergyConstants {
    /// Paper Table 4 (pJ -> J).
    fn default() -> Self {
        EnergyConstants {
            e_pix_p2m: 148e-12,
            e_pix_baseline: 312e-12,
            e_adc_p2m: 41.9e-12,
            e_adc_baseline_c: 86.14e-12,
            e_adc_baseline_nc: 80.14e-12,
            e_com: 900e-12,
            e_mac: 1.568e-12,
            e_read: 0.0,
        }
    }
}

impl EnergyConstants {
    pub fn e_pix(&self, kind: PipelineKind) -> f64 {
        match kind {
            PipelineKind::P2m => self.e_pix_p2m,
            _ => self.e_pix_baseline,
        }
    }

    pub fn e_adc(&self, kind: PipelineKind) -> f64 {
        match kind {
            PipelineKind::P2m => self.e_adc_p2m,
            PipelineKind::BaselineCompressed => self.e_adc_baseline_c,
            PipelineKind::BaselineNonCompressed => self.e_adc_baseline_nc,
        }
    }

    /// "Cloud" scenario: feature maps leave the edge device; the paper
    /// notes the savings grow because communication dominates.  We model
    /// it as a multiplier on e_com (wireless/backhaul per-byte cost).
    pub fn with_com_multiplier(mut self, m: f64) -> Self {
        self.e_com *= m;
        self
    }
}

/// Table 5: delay-model parameters.
#[derive(Clone, Copy, Debug)]
pub struct DelayConstants {
    /// I/O band-width (bits)
    pub b_io: u64,
    /// weight representation bit-width
    pub b_w: u64,
    /// number of memory banks
    pub n_bank: u64,
    /// number of multiplication units
    pub n_mult: u64,
    /// sensor read delay \[s\]: (P2M, baseline)
    pub t_sens_p2m: f64,
    pub t_sens_baseline: f64,
    /// ADC operation delay \[s\]: (P2M, baseline)
    pub t_adc_p2m: f64,
    pub t_adc_baseline: f64,
    /// one multiply in the SoC \[s\]
    pub t_mult: f64,
    /// one SRAM read in the SoC \[s\]
    pub t_read: f64,
}

impl Default for DelayConstants {
    /// Paper Table 5.
    fn default() -> Self {
        DelayConstants {
            b_io: 64,
            b_w: 32,
            n_bank: 4,
            n_mult: 175,
            t_sens_p2m: 35.84e-3,
            t_sens_baseline: 39.2e-3,
            t_adc_p2m: 0.229e-3,
            t_adc_baseline: 4.58e-3,
            t_mult: 5.48e-9,
            t_read: 5.48e-9,
        }
    }
}

impl DelayConstants {
    pub fn t_sens(&self, kind: PipelineKind) -> f64 {
        match kind {
            PipelineKind::P2m => self.t_sens_p2m,
            _ => self.t_sens_baseline,
        }
    }

    pub fn t_adc(&self, kind: PipelineKind) -> f64 {
        match kind {
            PipelineKind::P2m => self.t_adc_p2m,
            _ => self.t_adc_baseline,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_values() {
        let e = EnergyConstants::default();
        assert_eq!(e.e_pix(PipelineKind::P2m), 148e-12);
        assert_eq!(e.e_pix(PipelineKind::BaselineCompressed), 312e-12);
        assert_eq!(e.e_adc(PipelineKind::P2m), 41.9e-12);
        assert_eq!(e.e_adc(PipelineKind::BaselineCompressed), 86.14e-12);
        assert_eq!(e.e_adc(PipelineKind::BaselineNonCompressed), 80.14e-12);
        assert_eq!(e.e_com, 900e-12);
        assert_eq!(e.e_mac, 1.568e-12);
    }

    #[test]
    fn table5_values() {
        let d = DelayConstants::default();
        assert_eq!(d.b_io, 64);
        assert_eq!(d.b_w, 32);
        assert_eq!(d.n_bank, 4);
        assert_eq!(d.n_mult, 175);
        assert_eq!(d.t_sens(PipelineKind::P2m), 35.84e-3);
        assert_eq!(d.t_sens(PipelineKind::BaselineCompressed), 39.2e-3);
        assert_eq!(d.t_adc(PipelineKind::P2m), 0.229e-3);
        assert_eq!(d.t_adc(PipelineKind::BaselineNonCompressed), 4.58e-3);
    }

    #[test]
    fn p2m_components_cheaper() {
        let e = EnergyConstants::default();
        assert!(e.e_pix_p2m < e.e_pix_baseline);
        assert!(e.e_adc_p2m < e.e_adc_baseline_c);
        let d = DelayConstants::default();
        assert!(d.t_adc_p2m < d.t_adc_baseline);
    }

    #[test]
    fn cloud_multiplier() {
        let e = EnergyConstants::default().with_com_multiplier(10.0);
        assert_eq!(e.e_com, 9e-9);
        assert_eq!(e.e_mac, 1.568e-12); // untouched
    }
}
