//! The paper's energy/delay model (Section 5.3, Eq. 4-8) over a whole
//! sensing-to-classification pipeline.
//!
//! Two sourcing modes for the workload numbers:
//!
//! * [`PipelineModel::from_arch`] — N_pix / N_mac / N_read derived from
//!   our architecture descriptors (self-consistent with the rest of the
//!   repo; our custom model is leaner than the paper's, see
//!   EXPERIMENTS.md);
//! * [`PipelineModel::from_paper_reported`] — N_mac taken from the
//!   paper's own Table 2 entries (1.93 G / 0.27 G), which reproduces the
//!   published 7.81x / 2.15x / 16.76x headline numbers.

use crate::energy::constants::{DelayConstants, EnergyConstants, PipelineKind};
use crate::model::arch::{ArchConfig, LayerSpec};

/// Eq. 4 terms \[J\].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyBreakdown {
    pub e_sens: f64,
    pub e_com: f64,
    pub e_mac: f64,
    pub e_read: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.e_sens + self.e_com + self.e_mac + self.e_read
    }
}

/// Eq. 7-8 terms \[s\].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DelayBreakdown {
    pub t_sens: f64,
    pub t_adc: f64,
    pub t_conv: f64,
}

impl DelayBreakdown {
    /// Eq. 8: sequential sensing -> ADC -> SoC.
    pub fn total_sequential(&self) -> f64 {
        self.t_sens + self.t_adc + self.t_conv
    }

    /// Conservative overlap assumption: max(T_sens + T_adc, T_conv).
    pub fn total_overlap(&self) -> f64 {
        (self.t_sens + self.t_adc).max(self.t_conv)
    }
}

/// One pipeline instance to evaluate.
#[derive(Clone, Debug)]
pub struct PipelineModel {
    pub kind: PipelineKind,
    /// values leaving the sensor (N_pix in Eq. 4)
    pub n_pix: u64,
    /// SoC multiply-accumulates (N_mac)
    pub n_mac: u64,
    /// SoC parameter reads (N_read)
    pub n_read: u64,
    /// per-layer specs for the Eq. 7 per-layer delay (None -> aggregate
    /// approximation from n_mac/n_read)
    pub layers: Option<Vec<LayerSpec>>,
}

impl PipelineModel {
    /// Build from an architecture descriptor.
    pub fn from_arch(kind: PipelineKind, cfg: &ArchConfig) -> Self {
        let layers: Vec<LayerSpec> =
            cfg.layers().into_iter().filter(|l| !l.in_pixel).collect();
        let m = crate::model::analysis::analyse(cfg);
        PipelineModel {
            kind,
            n_pix: m.sensor_output_elems,
            n_mac: m.soc_madds,
            n_read: layers.iter().map(LayerSpec::n_read).sum(),
            layers: Some(layers),
        }
    }

    /// Paper-reported workload (Table 2 / Table 4 of the paper, 560x560):
    /// reproduces the published headline ratios exactly-in-shape.
    pub fn from_paper_reported(kind: PipelineKind) -> Self {
        match kind {
            PipelineKind::P2m => PipelineModel {
                kind,
                n_pix: 112 * 112 * 8,
                // Table 2 custom: 0.27 G total minus the in-pixel stem
                // (112*112*75*8 = 7.5 M executed in the pixel array).
                n_mac: 270_000_000 - 7_526_400,
                n_read: 900_000,
                layers: None,
            },
            PipelineKind::BaselineCompressed => PipelineModel {
                kind,
                n_pix: 560 * 560 * 3,
                n_mac: 1_930_000_000, // Table 2 baseline
                n_read: 2_200_000,
                layers: None,
            },
            PipelineKind::BaselineNonCompressed => PipelineModel {
                kind,
                n_pix: 560 * 560 * 3,
                // Standard (non-aggressive) stem: 560 -> 279 first fmap;
                // downstream cost scales ~(279/112)^2 on the early stages.
                // The paper does not tabulate this model's MAdds; we use
                // the compressed model inflated by the early-stage ratio.
                n_mac: 3_300_000_000,
                n_read: 2_200_000,
                layers: None,
            },
        }
    }

    /// Eq. 4.
    pub fn energy(&self, e: &EnergyConstants) -> EnergyBreakdown {
        EnergyBreakdown {
            e_sens: (e.e_pix(self.kind) + e.e_adc(self.kind)) * self.n_pix as f64,
            e_com: e.e_com * self.n_pix as f64,
            e_mac: e.e_mac * self.n_mac as f64,
            e_read: e.e_read * self.n_read as f64,
        }
    }

    /// Eq. 7 for one layer.
    fn t_conv_layer(l: &LayerSpec, d: &DelayConstants) -> f64 {
        let weights = l.n_read(); // k^2 * (c_i/groups) * c_o
        let read_term =
            weights.div_ceil((d.b_io / d.b_w) * d.n_bank) as f64 * d.t_read;
        let mult_term = weights.div_ceil(d.n_mult) as f64
            * (l.h_out * l.w_out) as f64
            * d.t_mult;
        read_term + mult_term
    }

    /// Eq. 7 summed over SoC layers (or the aggregate approximation when
    /// per-layer specs are unavailable).
    pub fn t_conv(&self, d: &DelayConstants) -> f64 {
        match &self.layers {
            Some(layers) => layers.iter().map(|l| Self::t_conv_layer(l, d)).sum(),
            None => {
                let read = self.n_read.div_ceil((d.b_io / d.b_w) * d.n_bank) as f64
                    * d.t_read;
                let mult = (self.n_mac as f64 / d.n_mult as f64) * d.t_mult;
                read + mult
            }
        }
    }

    /// Eq. 8 components.
    pub fn delay(&self, d: &DelayConstants) -> DelayBreakdown {
        DelayBreakdown {
            t_sens: d.t_sens(self.kind),
            t_adc: d.t_adc(self.kind),
            t_conv: self.t_conv(d),
        }
    }

    /// Energy-delay product [J*s].
    pub fn edp(&self, e: &EnergyConstants, d: &DelayConstants, sequential: bool) -> f64 {
        let energy = self.energy(e).total();
        let delay = if sequential {
            self.delay(d).total_sequential()
        } else {
            self.delay(d).total_overlap()
        };
        energy * delay
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_models() -> (PipelineModel, PipelineModel, PipelineModel) {
        (
            PipelineModel::from_paper_reported(PipelineKind::P2m),
            PipelineModel::from_paper_reported(PipelineKind::BaselineCompressed),
            PipelineModel::from_paper_reported(PipelineKind::BaselineNonCompressed),
        )
    }

    #[test]
    fn energy_ratio_reproduces_7p81x() {
        // Paper Section 5.3: "P2M can yield an energy reduction of up to
        // 7.81x".  Our re-evaluation of Eq. 4 with Table 4 constants and
        // Table 2 workloads lands within ~15% of that.
        let (p2m, base_c, _) = paper_models();
        let e = EnergyConstants::default();
        let ratio = base_c.energy(&e).total() / p2m.energy(&e).total();
        assert!((6.5..9.5).contains(&ratio), "energy ratio {ratio}");
    }

    #[test]
    fn delay_ratio_reproduces_2p15x() {
        let (p2m, base_c, _) = paper_models();
        let d = DelayConstants::default();
        let ratio =
            base_c.delay(&d).total_sequential() / p2m.delay(&d).total_sequential();
        assert!((1.8..2.8).contains(&ratio), "delay ratio {ratio}");
    }

    #[test]
    fn edp_sequential_reproduces_16p76x() {
        let (p2m, base_c, _) = paper_models();
        let e = EnergyConstants::default();
        let d = DelayConstants::default();
        let ratio = base_c.edp(&e, &d, true) / p2m.edp(&e, &d, true);
        assert!((13.0..23.0).contains(&ratio), "EDP ratio {ratio}");
    }

    #[test]
    fn edp_overlap_reproduces_11x() {
        let (p2m, base_c, _) = paper_models();
        let e = EnergyConstants::default();
        let d = DelayConstants::default();
        let ratio = base_c.edp(&e, &d, false) / p2m.edp(&e, &d, false);
        assert!((9.0..16.0).contains(&ratio), "EDP overlap ratio {ratio}");
    }

    #[test]
    fn cloud_scenario_increases_p2m_advantage() {
        // Paper: "the energy savings is larger when the feature map needs
        // to be transferred ... to the cloud".
        let (p2m, base_c, _) = paper_models();
        let edge = EnergyConstants::default();
        let cloud = EnergyConstants::default().with_com_multiplier(10.0);
        let r_edge = base_c.energy(&edge).total() / p2m.energy(&edge).total();
        let r_cloud = base_c.energy(&cloud).total() / p2m.energy(&cloud).total();
        assert!(r_cloud > r_edge, "cloud {r_cloud} <= edge {r_edge}");
    }

    #[test]
    fn nc_baseline_worst() {
        let (_, base_c, base_nc) = paper_models();
        let e = EnergyConstants::default();
        let d = DelayConstants::default();
        assert!(base_nc.energy(&e).total() > base_c.energy(&e).total());
        assert!(
            base_nc.delay(&d).total_sequential() > base_c.delay(&d).total_sequential()
        );
    }

    #[test]
    fn from_arch_agrees_in_direction() {
        let p2m = PipelineModel::from_arch(
            PipelineKind::P2m,
            &ArchConfig::paper_p2m(560),
        );
        let base = PipelineModel::from_arch(
            PipelineKind::BaselineCompressed,
            &ArchConfig::paper_baseline(560),
        );
        let e = EnergyConstants::default();
        let d = DelayConstants::default();
        let er = base.energy(&e).total() / p2m.energy(&e).total();
        let dr = base.delay(&d).total_sequential() / p2m.delay(&d).total_sequential();
        // Our leaner custom model wins by MORE than the paper's 7.81x.
        assert!(er > 7.0, "energy ratio {er}");
        assert!(dr > 1.8, "delay ratio {dr}");
    }

    #[test]
    fn per_layer_tconv_close_to_aggregate() {
        // The per-layer Eq. 7 sum and the aggregate approximation must
        // agree within ~40% (ceil effects) — sanity for paper-mode.
        let cfg = ArchConfig::paper_baseline(560);
        let per_layer = PipelineModel::from_arch(PipelineKind::BaselineCompressed, &cfg);
        let d = DelayConstants::default();
        let t1 = per_layer.t_conv(&d);
        let aggregate = PipelineModel { layers: None, ..per_layer.clone() };
        let t2 = aggregate.t_conv(&d);
        let rel = (t1 - t2).abs() / t2;
        assert!(rel < 0.4, "per-layer {t1} vs aggregate {t2}");
    }

    #[test]
    fn breakdown_totals_sum() {
        let (p2m, ..) = paper_models();
        let e = EnergyConstants::default();
        let b = p2m.energy(&e);
        assert!((b.total() - (b.e_sens + b.e_com + b.e_mac + b.e_read)).abs() < 1e-18);
        let d = DelayConstants::default();
        let db = p2m.delay(&d);
        assert!(db.total_sequential() >= db.total_overlap());
    }

    #[test]
    fn sens_energy_dominated_by_pixel_count() {
        let (p2m, base_c, _) = paper_models();
        let e = EnergyConstants::default();
        // Baseline reads 9.375x more values off the sensor.
        let r = base_c.energy(&e).e_sens / p2m.energy(&e).e_sens;
        assert!((15.0..26.0).contains(&r), "{r}"); // 9.375 * (398/190)
    }
}
