//! Cross-module property tests that need no artifacts or PJRT: physical
//! sanity of the sensor -> analog -> ADC chain and failure injection.

use p2m::adc::SsAdc;
use p2m::analog::{TransferSurface, VariationModel};
use p2m::baseline::BaselineReadout;
use p2m::config::{AdcConfig, SensorConfig, SystemConfig};
use p2m::energy::{DelayConstants, EnergyConstants, PipelineKind, PipelineModel};
use p2m::frontend::{Fidelity, FramePlan};
use p2m::model::{analyse, ArchConfig, Stem};
use p2m::prop_assert;
use p2m::sensor::{expose, mosaic, tile_to_rgb, GreenPolicy, Image, SceneGen, Split};
use p2m::util::prop::Prop;
use p2m::util::rng::Rng;

fn plan_with(theta_scale: f64, res: usize, seed: u64, fidelity: Fidelity) -> FramePlan {
    let cfg = SystemConfig::for_resolution(res);
    let p = cfg.hyper.patch_len();
    let c = cfg.hyper.out_channels;
    let mut rng = Rng::seed(seed);
    let theta: Vec<f32> =
        (0..p * c).map(|_| (rng.range(-1.0, 1.0) * theta_scale) as f32).collect();
    FramePlan::build(
        cfg,
        &theta,
        vec![1.0; c],
        vec![0.5; c],
        TransferSurface::load_default(),
        fidelity,
    )
    .unwrap()
}

#[test]
fn brighter_scene_never_reduces_positive_only_channels() {
    // With all-positive weights the in-pixel conv is monotone in light.
    Prop::new("frontend monotone in illumination").cases(8).run(|rng| {
        let res = 10usize;
        let cfg = SystemConfig::for_resolution(res);
        let p = cfg.hyper.patch_len();
        let c = cfg.hyper.out_channels;
        let theta: Vec<f32> = (0..p * c).map(|_| rng.range(0.05, 0.6) as f32).collect();
        let engine = FramePlan::build(
            cfg,
            &theta,
            vec![1.0; c],
            vec![0.0; c],
            TransferSurface::load_default(),
            Fidelity::Functional,
        )
        .unwrap();
        let dim = Image::from_vec(res, res, 3, vec![0.2; res * res * 3]);
        let bright = Image::from_vec(res, res, 3, vec![0.8; res * res * 3]);
        let (a, _) = engine.process_once(&dim);
        let (b, _) = engine.process_once(&bright);
        for (x, y) in a.data.iter().zip(&b.data) {
            prop_assert!(y >= x, "bright {y} < dim {x}");
        }
        Ok(())
    });
}

#[test]
fn full_chain_scene_to_codes_is_stable_under_noise() {
    // scene -> photodiode (noisy) -> frontend: codes move by at most a
    // few LSB between exposures of the same scene (the repeatability a
    // camera vendor would spec).
    let res = 20usize;
    let engine = plan_with(0.8, res, 3, Fidelity::Functional);
    let scene = SceneGen::new(res, 4).image(1, 0, Split::Train);
    let sensor = SensorConfig::default().with_resolution(res);
    let mut rng = Rng::seed(5);
    let (a, _) = engine.process_once(&expose(&sensor, &scene, &mut rng));
    let (b, _) = engine.process_once(&expose(&sensor, &scene, &mut rng));
    let lsb = engine.cfg.adc.lsb() as f32;
    for (x, y) in a.data.iter().zip(&b.data) {
        assert!((x - y).abs() <= 4.0 * lsb, "{x} vs {y}");
    }
}

#[test]
fn bayer_path_composes_with_frontend() {
    // Full-res RGB scene -> RGGB mosaic -> tile to half-res RGB -> P2M.
    let res = 40usize; // mosaic halves to 20, divisible by k=5
    let scene = SceneGen::new(res, 9).image(1, 2, Split::Train);
    let rgb_half = tile_to_rgb(&mosaic(&scene), GreenPolicy::Average);
    assert_eq!((rgb_half.h, rgb_half.w), (20, 20));
    let engine = plan_with(0.8, 20, 7, Fidelity::Functional);
    let (acts, report) = engine.process_once(&rgb_half);
    assert_eq!((acts.h, acts.w, acts.c), (4, 4, 8));
    assert_eq!(report.output_bytes, 4 * 4 * 8);
}

#[test]
fn mismatch_scales_smoothly() {
    // Increasing process variation increases output deviation, but small
    // sigma keeps the codes close: failure-injection sanity.
    let res = 10usize;
    let nominal = plan_with(0.8, res, 11, Fidelity::EventAccurate);
    let img = SceneGen::new(res, 12).image(1, 0, Split::Train);
    let (base, _) = nominal.process_once(&img);
    let lsb = nominal.cfg.adc.lsb() as f32;
    let mut prev_dev = 0.0f32;
    for (i, mult) in [0.5, 2.0, 6.0].iter().enumerate() {
        let noisy = plan_with(0.8, res, 11, Fidelity::EventAccurate)
            .with_mismatch(&VariationModel::default().scaled(*mult), 42);
        let (out, _) = noisy.process_once(&img);
        let dev: f32 = out
            .data
            .iter()
            .zip(&base.data)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / out.data.len() as f32;
        assert!(
            dev >= prev_dev * 0.5,
            "deviation should roughly grow: {dev} after {prev_dev}"
        );
        if i == 0 {
            assert!(dev <= 4.0 * lsb, "small mismatch, small deviation: {dev}");
        }
        prev_dev = dev;
    }
}

#[test]
fn adc_bits_sweep_changes_resolution_not_range() {
    // Fig 7a's hardware axis: fewer bits -> coarser codes, same span.
    for bits in [4u32, 6, 8] {
        let cfg = AdcConfig { n_bits: bits, full_scale: 75.0, ..AdcConfig::default() };
        let adc = SsAdc::new(cfg);
        assert_eq!(adc.quantize(75.0), cfg.code_max());
        assert_eq!(adc.quantize(0.0), 0);
        let mid = adc.dequantize(adc.quantize(37.5));
        assert!((mid - 37.5).abs() <= cfg.lsb() / 2.0 + 1e-12);
    }
}

#[test]
fn energy_model_monotone_in_workload() {
    Prop::new("energy monotone in N_pix and N_mac").cases(32).run(|rng| {
        let e = EnergyConstants::default();
        let base = PipelineModel {
            kind: PipelineKind::P2m,
            n_pix: rng.usize(1_000, 1_000_000) as u64,
            n_mac: rng.usize(1_000, 1_000_000_000) as u64,
            n_read: 1000,
            layers: None,
        };
        let more_pix = PipelineModel { n_pix: base.n_pix * 2, ..base.clone() };
        let more_mac = PipelineModel { n_mac: base.n_mac * 2, ..base.clone() };
        prop_assert!(more_pix.energy(&e).total() > base.energy(&e).total());
        prop_assert!(more_mac.energy(&e).total() > base.energy(&e).total());
        let d = DelayConstants::default();
        prop_assert!(
            more_mac.delay(&d).total_sequential() >= base.delay(&d).total_sequential()
        );
        Ok(())
    });
}

#[test]
fn design_space_br_vs_area_tradeoff() {
    // More channels = more weight transistors per pixel AND less BR:
    // the co-design tension of Section 4.2, end to end.
    let mut last_br = f64::INFINITY;
    for c_o in [2usize, 4, 8, 16, 32] {
        let h = p2m::config::HyperParams {
            out_channels: c_o,
            ..p2m::config::HyperParams::default()
        };
        let br = p2m::compression::bandwidth_reduction(&h, 560, 12);
        assert!(br < last_br, "BR must fall as channels grow");
        last_br = br;
        let mut arch = ArchConfig::paper_p2m(560);
        arch.stem = Stem::P2m { k: 5, c_o };
        let m = analyse(&arch);
        assert!(m.sensor_output_elems == (112 * 112 * c_o) as u64);
    }
}

#[test]
fn baseline_readout_never_compresses() {
    Prop::new("baseline ships >= native bytes").cases(16).run(|rng| {
        let res = 2 * rng.usize(5, 60); // even for Bayer
        let cfg = SensorConfig::default().with_resolution(res);
        let ro = BaselineReadout::new(cfg, PipelineKind::BaselineCompressed);
        let img = Image::zeros(res, res, 3);
        let (_, r) = ro.process(&img);
        let rgb_bytes = (res * res * 3) as u64; // 8-bit equivalent
        prop_assert!(r.output_bytes > rgb_bytes, "{} <= {rgb_bytes}", r.output_bytes);
        Ok(())
    });
}
