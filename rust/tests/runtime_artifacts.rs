//! Integration: AOT artifacts load, compile and execute through PJRT.
//!
//! Skipped (early-return) when `artifacts/` has not been built.

use std::collections::BTreeMap;

use p2m::runtime::{Manifest, ModelBundle, Runtime, Tensor};
use p2m::sensor::{SceneGen, Split};

fn artifacts_built() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

fn image_tensor(res: usize, seed: u64, batch: usize) -> Tensor {
    let gen = SceneGen::new(res, seed);
    let mut data = Vec::with_capacity(batch * res * res * 3);
    for i in 0..batch {
        let img = gen.image((i % 2) as u8, i as u64, Split::Val);
        data.extend_from_slice(&img.data);
    }
    Tensor::f32(vec![batch, res, res, 3], data)
}

#[test]
fn frontend_executes_with_correct_shape() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let mut extra = BTreeMap::new();
    extra.insert("image", image_tensor(80, 7, 1));
    let outs = bundle.run("frontend_80_b1", &extra).unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].dims, vec![1, 16, 16, 8]);
    let acts = outs[0].as_f32().unwrap();
    // Quantised non-negative activations, bounded by full scale.
    let lsb = 75.0 / 255.0;
    for &v in acts {
        assert!(v >= 0.0 && v <= 75.0 + 1e-3);
        let code = v / lsb as f32;
        assert!((code - code.round()).abs() < 1e-3, "{v}");
    }
}

#[test]
fn full_model_classifies() {
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let mut extra = BTreeMap::new();
    extra.insert("image", image_tensor(80, 9, 1));
    let outs = bundle.run("full_80_b1", &extra).unwrap();
    assert_eq!(outs[0].dims, vec![1, 2]);
    let logits = outs[0].as_f32().unwrap();
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn frontend_plus_backbone_equals_full() {
    // Composition: backbone(frontend(x)) must equal full(x) — they were
    // lowered from the same jax function split at the sensor boundary.
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let img = image_tensor(80, 21, 1);

    let mut extra = BTreeMap::new();
    extra.insert("image", img.clone());
    let acts = bundle.run("frontend_80_b1", &extra).unwrap().remove(0);
    let mut extra2 = BTreeMap::new();
    extra2.insert("acts", acts);
    let via_split = bundle.run("backbone_80_b1", &extra2).unwrap().remove(0);

    let mut extra3 = BTreeMap::new();
    extra3.insert("image", img);
    let via_full = bundle.run("full_80_b1", &extra3).unwrap().remove(0);

    let a = via_split.as_f32().unwrap();
    let b = via_full.as_f32().unwrap();
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() < 1e-4, "split {x} vs full {y}");
    }
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let b = bundle.entry.train_batch;
    let x = image_tensor(80, 3, b);
    let y = Tensor::i32(vec![b], (0..b as i32).map(|i| i % 2).collect());
    let first = bundle.train_step(x.clone(), y.clone(), 0.05).unwrap();
    assert!(first.is_finite());
    let mut last = first;
    for _ in 0..4 {
        last = bundle.train_step(x.clone(), y.clone(), 0.05).unwrap();
    }
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}

#[test]
fn eval_step_reports_counts() {
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let b = bundle.entry.eval_batch;
    let x = image_tensor(80, 5, b);
    let y = Tensor::i32(vec![b], (0..b as i32).map(|i| i % 2).collect());
    let (loss, correct) = bundle.eval_step(x, y).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(correct <= b as u32);
}

#[test]
fn batch8_variants_execute() {
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let mut extra = BTreeMap::new();
    extra.insert("image", image_tensor(80, 11, 8));
    let outs = bundle.run("full_80_b8", &extra).unwrap();
    assert_eq!(outs[0].dims, vec![8, 2]);
}
