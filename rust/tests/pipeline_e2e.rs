//! Integration: the whole smart-camera pipeline (capture -> in-pixel
//! frontend -> link -> batcher -> PJRT backbone) and its baseline twin.

use p2m::coordinator::{
    baseline_sensor, p2m_sensor_from_bundle, run_pipeline, Backpressure, Metrics,
    PipelineConfig,
};
use p2m::frontend::Fidelity;
use p2m::runtime::{Manifest, ModelBundle, Runtime};

fn artifacts_built() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

#[test]
fn p2m_pipeline_processes_all_frames_lossless() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
    let cfg = PipelineConfig {
        n_frames: 12,
        batch: 8,
        backpressure: Backpressure::Block,
        ..PipelineConfig::default()
    };
    let metrics = Metrics::new();
    let stats = run_pipeline(&mut bundle, sensor, &cfg, &metrics).unwrap();
    assert_eq!(stats.frames_captured, 12);
    assert_eq!(stats.frames_classified, 12);
    assert_eq!(stats.frames_dropped, 0);
    assert!(stats.batches >= 2); // 12 frames / batch 8 -> at least 2
    // Bandwidth: each frame ships 16*16*8 8-bit codes = 2048 bytes.
    assert_eq!(stats.bytes_from_sensor, 12 * 2048);
    assert!(stats.throughput_fps > 0.0);
    assert!(stats.latency_p95_s >= stats.latency_mean_s * 0.5);
}

#[test]
fn baseline_pipeline_ships_raw_pixels() {
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let cfg = PipelineConfig { n_frames: 6, batch: 1, ..PipelineConfig::default() };
    let metrics = Metrics::new();
    let stats =
        run_pipeline(&mut bundle, baseline_sensor(80), &cfg, &metrics).unwrap();
    assert_eq!(stats.frames_classified, 6);
    // Baseline: 80*80*3 RGB values -> 4/3 Bayer samples at 12 bits.
    let per_frame = (80 * 80 * 3) as u64 * 4 / 3 * 12 / 8;
    assert_eq!(stats.bytes_from_sensor, 6 * per_frame);
}

#[test]
fn p2m_link_bandwidth_beats_baseline() {
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let metrics = Metrics::new();
    let cfg = PipelineConfig { n_frames: 4, batch: 1, ..PipelineConfig::default() };
    let p2m_sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
    let p2m = run_pipeline(&mut bundle, p2m_sensor, &cfg, &metrics).unwrap();
    let base = run_pipeline(&mut bundle, baseline_sensor(80), &cfg, &metrics).unwrap();
    let ratio = base.bytes_from_sensor as f64 / p2m.bytes_from_sensor as f64;
    // Eq. 2 at identical conv hyper-parameters: 18.75x.
    assert!((ratio - 18.75).abs() < 0.2, "measured link BR {ratio}");
}

#[test]
fn drop_policy_bounds_queue_under_slow_consumer() {
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
    let cfg = PipelineConfig {
        n_frames: 10,
        batch: 1,
        queue_capacity: 2,
        backpressure: Backpressure::DropNewest,
        ..PipelineConfig::default()
    };
    let metrics = Metrics::new();
    let stats = run_pipeline(&mut bundle, sensor, &cfg, &metrics).unwrap();
    assert_eq!(stats.frames_captured, 10);
    assert_eq!(
        stats.frames_classified + stats.frames_dropped,
        stats.frames_captured,
        "conservation under drops"
    );
    assert!(stats.queue_high_watermark <= 2);
}
