//! Integration: the whole smart-camera pipeline (capture -> in-pixel
//! frontend -> link -> batcher -> PJRT backbone) and its baseline twin.

use p2m::coordinator::{
    baseline_sensor, p2m_plan_from_bundle, p2m_sensor_from_bundle, run_pipeline,
    Backpressure, Metrics, PipelineConfig, SensorCompute,
};
use p2m::frontend::Fidelity;
use p2m::runtime::{Manifest, ModelBundle, Runtime};

fn artifacts_built() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

#[test]
fn p2m_pipeline_processes_all_frames_lossless() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
    let cfg = PipelineConfig {
        n_frames: 12,
        batch: 8,
        backpressure: Backpressure::Block,
        ..PipelineConfig::default()
    };
    let metrics = Metrics::new();
    let stats = run_pipeline(&mut bundle, sensor, &cfg, &metrics).unwrap();
    assert_eq!(stats.frames_captured, 12);
    assert_eq!(stats.frames_classified, 12);
    assert_eq!(stats.frames_dropped, 0);
    assert!(stats.batches >= 2); // 12 frames / batch 8 -> at least 2
    // Dense wire: each frame ships 16*16*8 f32 values = 8192 bytes.
    assert_eq!(stats.bytes_from_sensor, 12 * 8192);
    assert!(stats.throughput_fps > 0.0);
    assert!(stats.latency_p95_s >= stats.latency_mean_s * 0.5);
}

#[test]
fn baseline_pipeline_ships_raw_pixels() {
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let cfg = PipelineConfig { n_frames: 6, batch: 1, ..PipelineConfig::default() };
    let metrics = Metrics::new();
    let stats =
        run_pipeline(&mut bundle, baseline_sensor(80), &cfg, &metrics).unwrap();
    assert_eq!(stats.frames_classified, 6);
    // Dense wire: 80*80*3 f32 pixels per frame (the modelled 12-bit
    // Bayer readout lives in baseline::ReadoutReport / compression).
    let per_frame = (80 * 80 * 3) as u64 * 4;
    assert_eq!(stats.bytes_from_sensor, 6 * per_frame);
}

#[test]
fn p2m_link_bandwidth_beats_baseline() {
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let metrics = Metrics::new();
    let cfg = PipelineConfig { n_frames: 4, batch: 1, ..PipelineConfig::default() };
    let p2m_sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
    let p2m = run_pipeline(&mut bundle, p2m_sensor, &cfg, &metrics).unwrap();
    let base = run_pipeline(&mut bundle, baseline_sensor(80), &cfg, &metrics).unwrap();
    // Dense-vs-dense measures the spatial compression I/O = 9.375x ...
    let ratio = base.bytes_from_sensor as f64 / p2m.bytes_from_sensor as f64;
    assert!((ratio - 9.375).abs() < 0.01, "measured dense link ratio {ratio}");
    // ... and the quantized wire adds the 32/8 precision credit: the
    // measured payload drops another 4x to exactly the Eq. 2 P2M side.
    let plan = p2m_plan_from_bundle(&bundle, Fidelity::Functional).unwrap();
    let quant = run_pipeline(
        &mut bundle,
        SensorCompute::p2m_quantized(plan),
        &cfg,
        &metrics,
    )
    .unwrap();
    assert_eq!(p2m.bytes_from_sensor, 4 * quant.bytes_from_sensor);
    assert_eq!(quant.correct, p2m.correct, "wire format must not change decisions");
}

#[test]
fn drop_policy_bounds_queue_under_slow_consumer() {
    if !artifacts_built() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
    let cfg = PipelineConfig {
        n_frames: 10,
        batch: 1,
        queue_capacity: 2,
        backpressure: Backpressure::DropNewest,
        ..PipelineConfig::default()
    };
    let metrics = Metrics::new();
    let stats = run_pipeline(&mut bundle, sensor, &cfg, &metrics).unwrap();
    assert_eq!(stats.frames_captured, 10);
    assert_eq!(
        stats.frames_classified + stats.frames_dropped,
        stats.frames_captured,
        "conservation under drops"
    );
    assert!(stats.queue_high_watermark <= 2);
}
