//! Integration: the sharded multi-camera fleet — determinism for fixed
//! seeds, per-camera-to-aggregate accounting, exact backpressure drop
//! accounting under a tiny link, and the quantized wire format
//! (dense-vs-quantized decision parity + Eq. 2 payload accounting).
//! Needs no artifacts or PJRT: the producers use deterministic synthetic
//! stem weights and the consumer the pure-rust mean-threshold backend.

use std::sync::Arc;
use std::time::Duration;

use p2m::compression;
use p2m::config::HyperParams;
use p2m::coordinator::{
    heterogeneous_fleet_sensors, run_fleet, synthetic_fleet_sensors,
    synthetic_frame_plan, Backpressure, BatchClassifier, CameraSpec, FleetConfig,
    FleetStats, MeanThresholdClassifier, Metrics, SensorCompute, ShapeKey, WireFormat,
    WirePayload,
};
use p2m::frontend::Fidelity;

const RES: usize = 40;
/// Dense wire: 40x40 input -> 8x8x8 f32 values per frame on the link.
const DENSE_BYTES_PER_FRAME: u64 = 8 * 8 * 8 * 4;
/// Quantized wire: the same frame as 8-bit ADC codes (the Eq. 2 payload).
const QUANT_BYTES_PER_FRAME: u64 = 8 * 8 * 8;

fn base_cfg() -> FleetConfig {
    FleetConfig {
        n_cameras: 4,
        frames_per_camera: 8,
        batch: 8,
        queue_capacity: 16,
        backpressure: Backpressure::Block,
        base_seed: 0xF1EE7,
        ..FleetConfig::default()
    }
}

fn run_wire<C: BatchClassifier>(
    classifier: &mut C,
    cfg: &FleetConfig,
    wire: WireFormat,
) -> FleetStats {
    let sensors =
        synthetic_fleet_sensors(RES, Fidelity::Functional, cfg.n_cameras, wire).unwrap();
    run_fleet(classifier, sensors, cfg, &Metrics::new()).unwrap()
}

fn run_with<C: BatchClassifier>(classifier: &mut C, cfg: &FleetConfig) -> FleetStats {
    run_wire(classifier, cfg, WireFormat::Dense)
}

/// Deterministic outcome of one camera: everything reproducible for a
/// fixed seed under a lossless link and a pure classifier.
fn outcome(stats: &FleetStats) -> Vec<(u64, u64, u64, u64, u64)> {
    stats
        .per_camera
        .iter()
        .map(|st| {
            (
                st.frames_captured,
                st.frames_classified,
                st.frames_dropped,
                st.bytes_from_sensor,
                st.correct,
            )
        })
        .collect()
}

#[test]
fn four_camera_fleet_is_deterministic_for_fixed_seeds() {
    let cfg = base_cfg();
    let a = run_with(&mut MeanThresholdClassifier::new(0.5), &cfg);
    let b = run_with(&mut MeanThresholdClassifier::new(0.5), &cfg);
    assert_eq!(outcome(&a), outcome(&b), "same seeds must give same outcome");
    for st in &a.per_camera {
        assert_eq!(st.frames_captured, 8);
        assert_eq!(st.frames_classified, 8);
        assert_eq!(st.frames_dropped, 0);
        assert_eq!(st.bytes_from_sensor, 8 * DENSE_BYTES_PER_FRAME);
    }
    // Seed *sensitivity* (that base_seed actually reaches the scene
    // streams) is pinned at payload level by
    // camera_seeds_reach_the_scene_stream below — the stats tuple alone
    // cannot distinguish seeds when the classifier output coincides.
}

/// Backend that records a quantised checksum of every payload it sees
/// (in arrival order) and predicts nothing useful — used to observe the
/// actual frame data a seed produces.
#[derive(Default)]
struct RecordingBackend {
    sums: Vec<u64>,
}

impl BatchClassifier for RecordingBackend {
    fn classify(&mut self, batch: &[&WirePayload]) -> anyhow::Result<Vec<u8>> {
        for payload in batch {
            // Ingest-dequantise, then checksum: identical for a dense
            // frame and its quantized re-encoding.
            let img = payload.to_image();
            self.sums
                .push(img.data.iter().map(|&v| (v * 1024.0) as u64).sum());
        }
        Ok(vec![0; batch.len()])
    }
}

#[test]
fn camera_seeds_reach_the_scene_stream() {
    // Single camera + batch 1 makes the arrival order the capture order,
    // so the recorded payload trace is fully deterministic.
    let trace = |seed: u64| -> Vec<u64> {
        let cfg = FleetConfig {
            n_cameras: 1,
            frames_per_camera: 6,
            batch: 1,
            queue_capacity: 16,
            backpressure: Backpressure::Block,
            base_seed: seed,
            ..FleetConfig::default()
        };
        let mut rec = RecordingBackend::default();
        run_with(&mut rec, &cfg);
        rec.sums
    };
    let a = trace(1);
    assert_eq!(a.len(), 6);
    assert_eq!(a, trace(1), "same seed must replay the same payloads");
    assert_ne!(a, trace(2), "different seeds must change the frame payloads");
}

#[test]
fn fleet_builds_exactly_one_shared_plan() {
    // N cameras, one compiled FramePlan: every sensor holds the same Arc
    // and nothing else does (one curve-fit load + one fold per fleet).
    let sensors =
        synthetic_fleet_sensors(RES, Fidelity::Functional, 5, WireFormat::Dense).unwrap();
    let first = sensors[0].plan().unwrap();
    assert!(
        sensors.iter().all(|s| Arc::ptr_eq(s.plan().unwrap(), first)),
        "all cameras must share the same plan instance"
    );
    assert_eq!(Arc::strong_count(first), 5, "exactly one plan for 5 cameras");
}

#[test]
fn shared_plan_fleet_payload_identical_to_private_plans() {
    // Sharing one Arc<FramePlan> across the fleet must be a pure
    // construction change: the payloads crossing the links are identical
    // to the old one-independent-engine-per-camera construction.
    let cfg = base_cfg();
    let shared =
        synthetic_fleet_sensors(RES, Fidelity::Functional, cfg.n_cameras, WireFormat::Dense)
            .unwrap();
    let private: Vec<SensorCompute> = (0..cfg.n_cameras)
        .map(|_| {
            SensorCompute::p2m(synthetic_frame_plan(RES, Fidelity::Functional).unwrap())
        })
        .collect();
    let checksums = |sensors: Vec<SensorCompute>| -> Vec<u64> {
        let mut rec = RecordingBackend::default();
        run_fleet(&mut rec, sensors, &cfg, &Metrics::new()).unwrap();
        // Arrival order interleaves cameras nondeterministically; the
        // payload multiset is the deterministic contract.
        let mut sums = rec.sums;
        sums.sort_unstable();
        sums
    };
    assert_eq!(checksums(shared), checksums(private));
}

#[test]
fn per_camera_stats_sum_to_aggregate() {
    let stats = run_with(&mut MeanThresholdClassifier::new(0.5), &base_cfg());
    let sum = |f: fn(&p2m::coordinator::PipelineStats) -> u64| -> u64 {
        stats.per_camera.iter().map(f).sum()
    };
    assert_eq!(sum(|s| s.frames_captured), stats.aggregate.frames_captured);
    assert_eq!(sum(|s| s.frames_classified), stats.aggregate.frames_classified);
    assert_eq!(sum(|s| s.frames_dropped), stats.aggregate.frames_dropped);
    assert_eq!(sum(|s| s.correct), stats.aggregate.correct);
    assert_eq!(sum(|s| s.bytes_from_sensor), stats.aggregate.bytes_from_sensor);
    let max_hwm =
        stats.per_camera.iter().map(|s| s.queue_high_watermark).max().unwrap();
    assert_eq!(stats.aggregate.queue_high_watermark, max_hwm);
    // Batches mix cameras, so they are accounted on the aggregate only.
    assert!(stats.aggregate.batches >= stats.aggregate.frames_classified / 8);
    assert!(stats.per_camera.iter().all(|s| s.batches == 0));
}

/// Wraps a backend with a fixed per-batch delay: a deliberately slow SoC
/// to force the tiny link into its backpressure policy.
struct SlowBackend<C>(C, Duration);

impl<C: BatchClassifier> BatchClassifier for SlowBackend<C> {
    fn classify(&mut self, batch: &[&WirePayload]) -> anyhow::Result<Vec<u8>> {
        std::thread::sleep(self.1);
        self.0.classify(batch)
    }
}

#[test]
fn drop_accounting_stays_exact_under_tiny_queue() {
    let cfg = FleetConfig {
        n_cameras: 4,
        frames_per_camera: 12,
        batch: 1,
        queue_capacity: 1,
        backpressure: Backpressure::DropNewest,
        base_seed: 3,
        ..FleetConfig::default()
    };
    let mut slow = SlowBackend(MeanThresholdClassifier::new(0.5), Duration::from_millis(2));
    let stats = run_with(&mut slow, &cfg);
    for (ci, st) in stats.per_camera.iter().enumerate() {
        assert_eq!(st.frames_captured, 12, "camera {ci} capture count");
        assert_eq!(
            st.frames_classified + st.frames_dropped,
            st.frames_captured,
            "camera {ci}: conservation under drops"
        );
        assert!(st.queue_high_watermark <= 1, "camera {ci} hwm");
        // Bytes are charged only for frames that crossed the link.
        assert_eq!(st.bytes_from_sensor, st.frames_classified * DENSE_BYTES_PER_FRAME);
    }
    assert_eq!(
        stats.aggregate.frames_classified + stats.aggregate.frames_dropped,
        stats.aggregate.frames_captured
    );
}

#[test]
fn quantized_fleet_agrees_with_dense_and_matches_eq2_payload() {
    // The tentpole acceptance pin: with the quantized wire format the
    // fleet's per-camera decisions agree with the dense-f32 path (the
    // ingest dequantisation is bit-identical, so agreement is 100% >=
    // the 99% bar), and every byte crossing a shard link is exactly the
    // Eq. 2 payload: p2m_bits_per_frame / 8 per frame.
    let cfg = base_cfg();
    let dense = run_with(&mut MeanThresholdClassifier::new(0.5), &cfg);
    let quant = run_wire(&mut MeanThresholdClassifier::new(0.5), &cfg, WireFormat::Quantized);

    let eq2_bytes = compression::p2m_bits_per_frame(&HyperParams::default(), RES).div_ceil(8);
    assert_eq!(eq2_bytes, QUANT_BYTES_PER_FRAME);
    for (ci, (d, q)) in dense.per_camera.iter().zip(&quant.per_camera).enumerate() {
        assert_eq!(q.frames_classified, d.frames_classified, "camera {ci}");
        assert_eq!(
            q.correct, d.correct,
            "camera {ci}: quantized decisions must agree with the dense path"
        );
        assert_eq!(
            q.bytes_from_sensor,
            q.frames_classified * eq2_bytes,
            "camera {ci}: measured payload must equal the Eq. 2 model exactly"
        );
        assert_eq!(d.bytes_from_sensor, 4 * q.bytes_from_sensor, "f32 -> 8-bit shrink");
    }
    assert_eq!(quant.aggregate.correct, dense.aggregate.correct);
}

#[test]
fn quantized_payloads_dequantise_to_the_dense_payloads() {
    // Payload-level identity: the checksum multiset a recording backend
    // sees is unchanged by the wire format — quantize/dequantize is a
    // pure re-encoding of every frame that crosses a link.
    let cfg = base_cfg();
    let checksums = |wire: WireFormat| -> Vec<u64> {
        let mut rec = RecordingBackend::default();
        run_wire(&mut rec, &cfg, wire);
        let mut sums = rec.sums;
        sums.sort_unstable();
        sums
    };
    assert_eq!(checksums(WireFormat::Dense), checksums(WireFormat::Quantized));
}

#[test]
fn heterogeneous_fleet_end_to_end_accounting() {
    // Mixed resolutions, bit depths and wire formats in one run_fleet
    // call: plans dedupe by design, batches stay shape-pure (enforced
    // by the consumer — a mixed batch is a hard error), per-camera and
    // per-shape stats both sum to the aggregate, and the run is
    // deterministic.
    let specs = vec![
        CameraSpec::new(0, RES, 8, WireFormat::Quantized),
        CameraSpec::new(1, RES, 8, WireFormat::Quantized),
        CameraSpec::new(2, 20, 6, WireFormat::Quantized),
        CameraSpec::new(3, 80, 8, WireFormat::Dense),
    ];
    let mk = || -> FleetStats {
        let (sensors, bank) = heterogeneous_fleet_sensors(&specs).unwrap();
        assert_eq!(bank.len(), 3, "two identical cameras share one plan");
        let cfg = FleetConfig {
            n_cameras: 4,
            frames_per_camera: 8,
            batch: 4,
            cameras: Some(specs.clone()),
            base_seed: 0xF1EE7,
            ..FleetConfig::default()
        };
        run_fleet(&mut MeanThresholdClassifier::new(0.5), sensors, &cfg, &Metrics::new())
            .unwrap()
    };
    let stats = mk();
    assert_eq!(stats.aggregate.frames_classified, 32);
    assert_eq!(stats.aggregate.frames_dropped, 0);
    assert_eq!(stats.per_shape.len(), 3);
    // 40px/q8 (cameras 0+1), 20px/q6, 80px dense.
    assert!(stats.per_shape.contains_key(&ShapeKey { h: 8, w: 8, c: 8, bits: 8 }));
    assert!(stats.per_shape.contains_key(&ShapeKey { h: 4, w: 4, c: 8, bits: 6 }));
    assert!(stats.per_shape.contains_key(&ShapeKey { h: 16, w: 16, c: 8, bits: 0 }));
    let frames: u64 = stats.per_shape.values().map(|s| s.frames_classified).sum();
    let bytes: u64 = stats.per_shape.values().map(|s| s.bytes_from_sensor).sum();
    let batches: u64 = stats.per_shape.values().map(|s| s.batches).sum();
    assert_eq!(frames, stats.aggregate.frames_classified);
    assert_eq!(bytes, stats.aggregate.bytes_from_sensor);
    assert_eq!(batches, stats.aggregate.batches);
    // Quantized Eq. 2 payloads per camera: q8 = 512 B, q6 = 96 B/frame.
    assert_eq!(stats.per_camera[0].bytes_from_sensor, 8 * 512);
    assert_eq!(stats.per_camera[2].bytes_from_sensor, 8 * 96);
    assert_eq!(stats.per_camera[3].bytes_from_sensor, 8 * 16 * 16 * 8 * 4);
    // Deterministic outcome for the fixed seed set.
    assert_eq!(outcome(&stats), outcome(&mk()));
}

#[test]
fn blocking_fleet_is_lossless_even_when_slow() {
    let cfg = FleetConfig {
        n_cameras: 2,
        frames_per_camera: 6,
        batch: 2,
        queue_capacity: 1,
        backpressure: Backpressure::Block,
        base_seed: 5,
        ..FleetConfig::default()
    };
    let mut slow = SlowBackend(MeanThresholdClassifier::new(0.5), Duration::from_millis(1));
    let stats = run_with(&mut slow, &cfg);
    assert_eq!(stats.aggregate.frames_dropped, 0);
    assert_eq!(stats.aggregate.frames_classified, 12);
}
