//! Integration: the operability plane — live `/metrics` + `/healthz`
//! over a real TCP socket, admin verbs mutating a *running* scenario
//! through the deterministic cell machinery (hot-add digest parity,
//! vacate-without-trace removal, live pool resize), and the
//! `ShedOldest` overload policy's exact per-camera/per-shape shed
//! accounting.  Needs no artifacts or PJRT; every socket binds an
//! ephemeral port.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use p2m::coordinator::{
    run_scenario, run_scenario_serve, Backpressure, BatchClassifier, CameraScript,
    CameraSpec, ControlPlane, HttpRequest, HttpServer, MeanThresholdClassifier, Metrics,
    Scenario, ScenarioReport, Segment, SegmentEnd, ShapeKey, WireFormat, WirePayload,
};

fn q8(id: u64, res: usize) -> CameraSpec {
    CameraSpec::new(id, res, 8, WireFormat::Quantized)
}

fn run_plain(scenario: &Scenario) -> ScenarioReport {
    let mut clf = MeanThresholdClassifier::new(0.5);
    run_scenario(&mut clf, scenario, &Metrics::new()).unwrap()
}

/// One blocking HTTP exchange against the plane: returns (status, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect to the operability plane");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: p2m\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    let status = out
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("unparsable response: {out:?}"));
    let payload = out.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, payload)
}

/// Retry an admin verb until the run attaches (503 → retry); any other
/// non-200 status is a real failure.
fn admin_until_ok(addr: SocketAddr, method: &str, path: &str, body: &str) -> String {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (status, payload) = http(addr, method, path, body);
        match status {
            200 => return payload,
            503 if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(2));
            }
            other => panic!("{method} {path} answered {other}: {payload}"),
        }
    }
}

/// Serve `scenario` on an ephemeral port while `exercise` drives the
/// admin API from this thread; returns the run's report.
fn run_served(
    scenario: &Scenario,
    exercise: impl FnOnce(SocketAddr, &Arc<AtomicBool>),
) -> ScenarioReport {
    let metrics = Arc::new(Metrics::new());
    let plane = Arc::new(ControlPlane::new(metrics.clone()));
    let handler_plane = plane.clone();
    let server = HttpServer::bind("127.0.0.1:0")
        .unwrap()
        .spawn(Arc::new(move |req: &HttpRequest| handler_plane.handle(req)))
        .unwrap();
    let addr = server.local_addr();
    let done = Arc::new(AtomicBool::new(false));
    let mut report = None;
    std::thread::scope(|s| {
        let run_done = done.clone();
        let run_plane = &plane;
        let run_metrics = &metrics;
        let handle = s.spawn(move || {
            let mut clf = MeanThresholdClassifier::new(0.5);
            let r = run_scenario_serve(&mut clf, scenario, run_metrics, run_plane);
            run_done.store(true, Ordering::Relaxed);
            r
        });
        exercise(addr, &done);
        report = Some(handle.join().unwrap().unwrap());
    });
    server.stop();
    report.unwrap()
}

/// A paced anchor keeps the run open long enough for admin verbs to
/// land deterministically: `frames` at `fps` ≈ frames/fps seconds.
fn paced_anchor(spec: CameraSpec, frames: usize, fps: f64) -> CameraScript {
    CameraScript {
        spec,
        start_delay: Duration::ZERO,
        segments: vec![Segment::paced(frames, fps, SegmentEnd::Clean)],
    }
}

#[test]
fn healthz_and_metrics_serve_over_real_tcp() {
    let scenario = Scenario::new("serve-smoke", 5, vec![paced_anchor(q8(0, 40), 50, 250.0)]);
    let report = run_served(&scenario, |addr, _| {
        let (status, body) = http(addr, "GET", "/healthz", "");
        assert_eq!(status, 200);
        assert_eq!(body, "ok\n");
        // Wait for attach so the fleet extras are rendered too.
        admin_until_ok(addr, "POST", "/admin/pool/resize", "{\"workers\":2}");
        let (status, body) = http(addr, "GET", "/metrics", "");
        assert_eq!(status, 200);
        for needle in [
            "# TYPE p2m_scenario_frames_captured_total counter",
            "p2m_shape_queue_depth",
            "p2m_simd_tier",
            "p2m_run_open 1",
            "p2m_arena_hit_rate",
        ] {
            assert!(body.contains(needle), "missing {needle:?} in:\n{body}");
        }
        let (status, _) = http(addr, "GET", "/no-such-route", "");
        assert_eq!(status, 404);
    });
    assert_eq!(report.per_camera.len(), 1);
    assert_eq!(report.per_camera[0].stats.frames_classified, 50);
}

#[test]
fn admin_hot_add_digests_like_the_equivalent_scripted_scenario() {
    let seed = 11;
    let scenario = Scenario::new("hot-add", seed, vec![paced_anchor(q8(0, 40), 100, 250.0)]);
    let report = run_served(&scenario, |addr, _| {
        let body = admin_until_ok(
            addr,
            "POST",
            "/admin/camera",
            "{\"id\":7,\"resolution\":40,\"n_bits\":8,\"frames\":5}",
        );
        assert!(body.contains("\"slot\":1"), "{body}");
    });

    // The scripted twin: the same scenario with the admin camera
    // appended last (admin adds join with zero start delay, a single
    // clean free-running segment, and the same id-derived seed).
    let mut twin = scenario.clone();
    twin.cameras.push(CameraScript {
        spec: q8(7, 40),
        start_delay: Duration::ZERO,
        segments: vec![Segment::free(5, SegmentEnd::Clean)],
    });
    let scripted = run_plain(&twin);

    assert_eq!(report.per_camera.len(), 2, "anchor + hot-add");
    assert_eq!(report.per_camera[1].spec.id, 7);
    assert_eq!(report.per_camera[1].stats.frames_classified, 5);
    assert_eq!(
        report.digest(),
        scripted.digest(),
        "a live hot-add must ride the same deterministic paths as a scripted one"
    );
}

#[test]
fn admin_remove_before_first_frame_vacates_without_trace() {
    let seed = 23;
    // Camera 1 shares camera 0's design (same compiled plan) and joins
    // only after 800 ms — removing it before that leaves a run
    // indistinguishable from the scenario that never scripted it.
    let mut scenario =
        Scenario::new("vacate", seed, vec![paced_anchor(q8(0, 40), 60, 250.0)]);
    scenario.cameras.push(CameraScript {
        spec: q8(1, 40),
        start_delay: Duration::from_millis(800),
        segments: vec![Segment::free(4, SegmentEnd::Clean)],
    });
    let report = run_served(&scenario, |addr, _| {
        let body = admin_until_ok(addr, "DELETE", "/admin/camera/1", "");
        assert!(body.contains("\"id\":1"), "{body}");
    });

    let without = Scenario::new("vacate", seed, vec![paced_anchor(q8(0, 40), 60, 250.0)]);
    let plain = run_plain(&without);
    assert_eq!(report.per_camera.len(), 1, "the vacated camera left no report row");
    assert_eq!(report.per_camera[0].spec.id, 0);
    assert_eq!(
        report.digest(),
        plain.digest(),
        "a pre-start removal must leave the run as if the camera was never scripted"
    );
}

#[test]
fn serving_metrics_mid_run_never_perturbs_the_digest() {
    let seed = 41;
    let scenario = Scenario::canned("churn", seed).unwrap();
    let mut scrapes = 0u64;
    let report = run_served(&scenario, |addr, done| {
        // Live pool resize: answered 200, affects wall time only.
        let body = admin_until_ok(addr, "POST", "/admin/pool/resize", "{\"workers\":1}");
        assert!(body.contains("\"workers\":1"), "{body}");
        // Hammer /metrics for the whole run.
        while !done.load(Ordering::Relaxed) {
            let (status, body) = http(addr, "GET", "/metrics", "");
            assert_eq!(status, 200);
            assert!(body.contains("p2m_"), "empty exposition:\n{body}");
            scrapes += 1;
        }
    });
    assert!(scrapes > 0, "the run ended before a single scrape landed");
    let plain = run_plain(&scenario);
    assert_eq!(
        report.digest(),
        plain.digest(),
        "scraping /metrics and resizing the pool must never change outcomes"
    );
}

/// Classifier slow enough that a capacity-1 link under free-running
/// producers must shed: every batch costs 2 ms.
struct SlowClassifier;

impl BatchClassifier for SlowClassifier {
    fn classify(&mut self, batch: &[&WirePayload]) -> anyhow::Result<Vec<u8>> {
        std::thread::sleep(Duration::from_millis(2));
        Ok(vec![0; batch.len()])
    }
}

#[test]
fn shed_oldest_accounts_exactly_per_camera_and_per_shape() {
    // Two designs -> two shapes; capacity-1 links + a slow classifier
    // force sustained overload, so ShedOldest must evict.
    let mut scenario = Scenario::new(
        "overload",
        3,
        vec![
            CameraScript::steady(q8(0, 40), 60),
            CameraScript::steady(q8(1, 20), 60),
        ],
    );
    scenario.queue_capacity = 1;
    scenario.backpressure = Backpressure::ShedOldest;
    let mut clf = SlowClassifier;
    let report = run_scenario(&mut clf, &scenario, &Metrics::new()).unwrap();

    let a = &report.aggregate;
    assert!(a.frames_shed > 0, "a capacity-1 link under overload must shed");
    assert_eq!(a.frames_dropped, 0, "ShedOldest never refuses the new frame");
    // Conservation, fleet-wide and per camera: every captured frame is
    // classified or shed — never silently lost.
    assert_eq!(a.frames_captured, a.frames_classified + a.frames_shed);
    let mut shed_by_shape = std::collections::BTreeMap::new();
    for cam in &report.per_camera {
        let st = &cam.stats;
        assert_eq!(st.frames_captured, cam.scripted_frames);
        assert_eq!(
            st.frames_captured,
            st.frames_classified + st.frames_shed,
            "camera {}",
            cam.spec.id
        );
        let shape = ShapeKey {
            h: if cam.spec.resolution == 40 { 8 } else { 4 },
            w: if cam.spec.resolution == 40 { 8 } else { 4 },
            c: 8,
            bits: 8,
        };
        *shed_by_shape.entry(shape).or_insert(0u64) += st.frames_shed;
    }
    // Exact per-shape shed accounting: the per-shape counters equal the
    // sums of their cameras' shed counts.
    for (shape, expected) in shed_by_shape {
        assert_eq!(
            report.per_shape.get(&shape).map_or(0, |ss| ss.frames_shed),
            expected,
            "{shape}"
        );
    }
    // Per-camera shed sums to the aggregate.
    let sum: u64 = report.per_camera.iter().map(|c| c.stats.frames_shed).sum();
    assert_eq!(sum, a.frames_shed);
}

#[test]
fn admin_camera_answers_422_for_multi_segment_scripts() {
    let scenario =
        Scenario::new("segments-422", 7, vec![paced_anchor(q8(0, 40), 80, 250.0)]);
    let report = run_served(&scenario, |addr, _| {
        // Any 200 from an admin verb proves the run is attached.
        admin_until_ok(addr, "POST", "/admin/pool/resize", "{\"workers\":1}");
        // The old handler silently honoured only the first segment of a
        // multi-segment script; now the lie is a loud 422.
        let (status, body) = http(
            addr,
            "POST",
            "/admin/camera",
            "{\"id\":9,\"segments\":[{\"frames\":4},{\"frames\":4}]}",
        );
        assert_eq!(status, 422, "{body}");
        assert!(body.contains("\"ok\":false"), "{body}");
        assert!(body.contains("exactly one"), "{body}");
        let (status, body) =
            http(addr, "POST", "/admin/camera", "{\"id\":9,\"segments\":[]}");
        assert_eq!(status, 422, "an empty script is as unrunnable: {body}");
        // A single-entry script IS the one segment hot-adds run: honoured.
        let (status, body) = http(
            addr,
            "POST",
            "/admin/camera",
            "{\"id\":9,\"resolution\":40,\"segments\":[{\"frames\":5}]}",
        );
        assert_eq!(status, 200, "{body}");
        assert!(body.contains("\"ok\":true"), "{body}");
    });
    assert_eq!(report.per_camera.len(), 2, "rejected adds must leave no trace");
    assert_eq!(report.per_camera[1].spec.id, 9);
    assert_eq!(report.per_camera[1].stats.frames_classified, 5);
}

#[test]
fn admin_hot_add_event_wire_rides_the_sparse_path() {
    let seed = 13;
    let scenario =
        Scenario::new("hot-add-event", seed, vec![paced_anchor(q8(0, 40), 100, 250.0)]);
    let report = run_served(&scenario, |addr, _| {
        let body = admin_until_ok(
            addr,
            "POST",
            "/admin/camera",
            "{\"id\":4,\"resolution\":40,\"n_bits\":8,\"wire\":\"event\",\
             \"frames\":6,\"freeze\":true}",
        );
        assert!(body.contains("\"ok\":true"), "{body}");
    });
    assert_eq!(report.per_camera.len(), 2);
    let cam = &report.per_camera[1];
    assert_eq!(cam.spec.wire, WireFormat::Event);
    assert!(cam.spec.freeze);
    assert_eq!(cam.stats.frames_classified, 6);
    // One keyframe, then header-only frames on the frozen scene.
    assert_eq!(report.events.event_frames, 6);
    assert!(
        report.events.wire_bytes < report.events.dense_equiv_bytes,
        "{:?}",
        report.events
    );

    // Digest parity with the scripted twin of the same event camera.
    let mut twin = scenario.clone();
    twin.cameras.push(CameraScript {
        spec: CameraSpec::new(4, 40, 8, WireFormat::Event).with_freeze(true),
        start_delay: Duration::ZERO,
        segments: vec![Segment::free(6, SegmentEnd::Clean)],
    });
    let scripted = run_plain(&twin);
    assert_eq!(
        report.digest(),
        scripted.digest(),
        "an event-wire hot-add must ride the same deterministic paths as a scripted one"
    );
}

#[test]
fn admin_event_hot_add_requires_block_backpressure() {
    let mut scenario =
        Scenario::new("event-409", 9, vec![paced_anchor(q8(0, 40), 80, 250.0)]);
    scenario.backpressure = Backpressure::DropNewest;
    run_served(&scenario, |addr, _| {
        admin_until_ok(addr, "POST", "/admin/pool/resize", "{\"workers\":1}");
        let (status, body) =
            http(addr, "POST", "/admin/camera", "{\"id\":2,\"wire\":\"event\"}");
        assert_eq!(status, 409, "{body}");
        assert!(body.contains("Backpressure::Block"), "{body}");
    });
}
