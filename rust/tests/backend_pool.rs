//! Integration: the pooled classify stage + native integer backend —
//! conservation (frames in == predictions out) through the pool,
//! deterministic outcomes across 1/2/4 workers under churn, scenario
//! digests invariant to pooling, and the `NativeBackend`
//! batch-regrouping property.  Needs no artifacts or PJRT.

use p2m::coordinator::{
    run_fleet, run_fleet_pooled, run_scenario, run_scenario_pooled, BatchClassifier,
    FleetConfig, FleetStats, Metrics, Scenario, WireFormat,
};
use p2m::coordinator::{synthetic_fleet_sensors, SensorCompute, WirePayload};
use p2m::frontend::Fidelity;
use p2m::model::NativeBackend;
use p2m::sensor::{Camera, Split};

/// The deterministic per-camera outcome tuple (timing excluded).
fn outcomes(stats: &FleetStats) -> Vec<(u64, u64, u64, u64)> {
    stats
        .per_camera
        .iter()
        .map(|st| (st.frames_captured, st.frames_classified, st.bytes_from_sensor, st.correct))
        .collect()
}

fn native_fleet(workers: usize, cfg: &FleetConfig) -> FleetStats {
    let sensors =
        synthetic_fleet_sensors(20, Fidelity::Functional, cfg.n_cameras, WireFormat::Quantized)
            .unwrap();
    if workers <= 1 {
        let mut clf = NativeBackend::new();
        run_fleet(&mut clf, sensors, cfg, &Metrics::new()).unwrap()
    } else {
        run_fleet_pooled(workers, |_| NativeBackend::new(), sensors, cfg, &Metrics::new())
            .unwrap()
    }
}

#[test]
fn pooled_native_fleet_conserves_frames_for_every_worker_count() {
    let cfg = FleetConfig {
        n_cameras: 4,
        frames_per_camera: 8,
        batch: 4,
        base_seed: 21,
        ..FleetConfig::default()
    };
    for workers in [1usize, 2, 4] {
        let stats = native_fleet(workers, &cfg);
        // Conservation: every captured frame came out as a prediction.
        assert_eq!(stats.aggregate.frames_captured, 32, "workers {workers}");
        assert_eq!(stats.aggregate.frames_classified, 32, "workers {workers}");
        assert_eq!(stats.aggregate.frames_dropped, 0, "workers {workers}");
        for st in &stats.per_camera {
            assert_eq!(st.frames_classified, st.frames_captured, "workers {workers}");
        }
    }
}

#[test]
fn native_fleet_outcomes_are_identical_across_worker_counts() {
    let cfg = FleetConfig {
        n_cameras: 3,
        frames_per_camera: 6,
        batch: 4,
        base_seed: 5,
        ..FleetConfig::default()
    };
    let direct = native_fleet(1, &cfg);
    for workers in [2usize, 4] {
        let pooled = native_fleet(workers, &cfg);
        assert_eq!(
            outcomes(&direct),
            outcomes(&pooled),
            "worker count {workers} changed per-camera outcomes"
        );
    }
}

#[test]
fn churn_scenario_digest_is_invariant_to_pooling_and_worker_count() {
    // The acceptance bar: scenario digests (which fold per-camera
    // classification outcomes) must be bit-identical between the direct
    // path and the pool at any worker count, with the native backend
    // doing real integer-MobileNetV2 work per frame.
    let scenario = Scenario::canned("churn", 17).unwrap();
    let direct = {
        let mut clf = NativeBackend::new();
        run_scenario(&mut clf, &scenario, &Metrics::new()).unwrap()
    };
    for workers in [1usize, 2, 4] {
        let pooled = run_scenario_pooled(
            workers,
            |_| NativeBackend::new(),
            &scenario,
            &Metrics::new(),
        )
        .unwrap();
        assert_eq!(
            direct.digest(),
            pooled.digest(),
            "digest moved at {workers} workers"
        );
    }
}

#[test]
fn crash_storm_survives_pool_reassembly_with_conservation() {
    // The CI smoke's property as a test: producer crashes + restarts on
    // the producer side, pooled classification on the consumer side —
    // every accepted frame still becomes exactly one prediction.
    let scenario = Scenario::canned("crash-storm", 3).unwrap();
    let report = run_scenario_pooled(
        4,
        |_| NativeBackend::new(),
        &scenario,
        &Metrics::new(),
    )
    .unwrap();
    assert_eq!(report.aggregate.frames_classified, 60);
    assert_eq!(report.aggregate.frames_dropped, 0);
    for cam in &report.per_camera {
        assert_eq!(cam.stats.frames_classified, cam.stats.frames_captured);
    }
    // And it reproduces.
    let again = run_scenario_pooled(
        4,
        |_| NativeBackend::new(),
        &scenario,
        &Metrics::new(),
    )
    .unwrap();
    assert_eq!(report.digest(), again.digest());
}

#[test]
fn native_backend_outputs_are_invariant_across_batch_regrouping() {
    // Property: for a stream of real frontend payloads, the native
    // backend's integer predictions do not depend on how the stream is
    // cut into batches — singletons, pairs, odd-sized chunks and the
    // whole stream agree element-wise.
    let plan = p2m::coordinator::synthetic_frame_plan(20, Fidelity::Functional).unwrap();
    let mut sensor = SensorCompute::p2m_quantized(plan.clone());
    let mut camera = Camera::new(plan.cfg.sensor, 99, Split::Test);
    let payloads: Vec<WirePayload> = (0..12)
        .map(|_| sensor.run_frame(&camera.capture().image, 1).0)
        .collect();
    let refs: Vec<&WirePayload> = payloads.iter().collect();

    let mut backend = NativeBackend::new();
    let whole = backend.classify(&refs).unwrap();
    assert_eq!(whole.len(), 12);
    for chunk_size in [1usize, 2, 3, 5, 7, 12] {
        let mut regrouped = Vec::new();
        for chunk in refs.chunks(chunk_size) {
            regrouped.extend(backend.classify(chunk).unwrap());
        }
        assert_eq!(whole, regrouped, "chunk size {chunk_size} changed predictions");
    }
    // A fresh backend instance (fresh lazy model compile) agrees too.
    let mut fresh = NativeBackend::new();
    assert_eq!(fresh.classify(&refs).unwrap(), whole);
    assert_eq!(fresh.models_compiled(), 1, "one shape, one compiled model");
}

#[test]
fn pooled_threshold_fleet_matches_quantized_dense_parity() {
    // Dense-vs-quantized parity (the wire format changes bytes, never
    // decisions) must survive the pooled classify stage.
    let cfg = FleetConfig {
        n_cameras: 3,
        frames_per_camera: 6,
        batch: 4,
        base_seed: 11,
        ..FleetConfig::default()
    };
    let run_wire = |wire: WireFormat| {
        let sensors =
            synthetic_fleet_sensors(20, Fidelity::Functional, cfg.n_cameras, wire).unwrap();
        run_fleet_pooled(
            3,
            |_| p2m::coordinator::MeanThresholdClassifier::new(0.5),
            sensors,
            &cfg,
            &Metrics::new(),
        )
        .unwrap()
    };
    let dense = run_wire(WireFormat::Dense);
    let quant = run_wire(WireFormat::Quantized);
    for (d, q) in dense.per_camera.iter().zip(&quant.per_camera) {
        assert_eq!(d.correct, q.correct);
        assert_eq!(d.frames_classified, q.frames_classified);
        assert_eq!(d.bytes_from_sensor, 4 * q.bytes_from_sensor);
    }
}
