//! Steady-state allocation contract of the plan/ctx split: once a
//! [`p2m::frontend::ExecCtx`] and an output buffer exist, processing a
//! frame through `FramePlan::process_into` — or its quantized wire
//! sibling `process_quantized_into` — performs **zero** heap
//! allocations, in both fidelities.
//!
//! This file is deliberately a single-test integration binary: the
//! counting global allocator below observes the whole process, so no
//! other test may run concurrently in it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use p2m::coordinator::synthetic_frame_plan;
use p2m::frontend::Fidelity;
use p2m::sensor::{Image, SceneGen, Split};
use p2m::util::arena::FrameArena;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_frame_processing_allocates_nothing() {
    for fidelity in [Fidelity::Functional, Fidelity::EventAccurate] {
        let plan = synthetic_frame_plan(20, fidelity).unwrap();
        if !plan.surface.is_poly() {
            // Device-fallback surface (curve-fit artifact deleted): the
            // unfolded reference path is still allocation-free but far
            // too slow for a routine test run.
            eprintln!("skipping: transfer surface did not fold");
            return;
        }
        let (ho, wo, c) = plan.cfg.out_dims();
        let gen = SceneGen::new(20, 7);
        let frames = [
            gen.image(1, 0, Split::Train),
            gen.image(0, 1, Split::Train),
            gen.image(1, 2, Split::Train),
        ];
        let mut ctx = plan.ctx();
        let mut out = Image::zeros(ho, wo, c);
        // Warm-up frame (everything is sized eagerly, but be explicit).
        let warm = plan.process_into(&frames[0], &mut ctx, &mut out);
        assert_eq!(warm.conversions, (ho * wo * c) as u64);

        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        let mut conversions = 0u64;
        for _ in 0..4 {
            for frame in &frames {
                conversions += plan.process_into(frame, &mut ctx, &mut out).conversions;
            }
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "{fidelity:?}: steady-state process_into must not allocate"
        );
        assert_eq!(conversions, 12 * (ho * wo * c) as u64);

        // The quantized wire sibling holds the same contract: with a
        // reused ctx + caller-owned QuantizedFrame, emitting the wire
        // payload allocates nothing either.
        let mut qframe = plan.quantized_frame();
        let warm_q = plan.process_quantized_into(&frames[0], &mut ctx, &mut qframe);
        assert_eq!(warm_q.conversions, (ho * wo * c) as u64);
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        let mut q_conversions = 0u64;
        for _ in 0..4 {
            for frame in &frames {
                q_conversions +=
                    plan.process_quantized_into(frame, &mut ctx, &mut qframe).conversions;
            }
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "{fidelity:?}: steady-state process_quantized_into must not allocate"
        );
        assert_eq!(q_conversions, 12 * (ho * wo * c) as u64);

        // The full swarm hot path — scene draw into an arena-recycled
        // capture buffer, quantized processing into an arena-backed
        // frame, wire packing into an arena-backed byte buffer, then
        // recycling everything — also allocates nothing once the
        // [`FrameArena`] is warm.  This is the per-frame cycle
        // `fire_cell` runs for every producer-pool camera.
        let arena = FrameArena::new();
        let mut cycle = |label: u8, idx: u64| -> u64 {
            let mut img = Image::zeros_in(20, 20, 3, &arena);
            gen.image_into(label, idx, Split::Train, &mut img);
            let mut qf = plan.quantized_frame_in(&arena);
            let report = plan.process_quantized_into(&img, &mut ctx, &mut qf);
            let mut wire = arena.take_u8(qf.wire_bytes() as usize);
            qf.pack_wire_into(&mut wire);
            arena.put_u8(wire);
            img.recycle(&arena);
            qf.recycle(&arena);
            report.conversions
        };
        // Warm lap: every size class misses once and seeds the pool.
        assert_eq!(cycle(1, 0), (ho * wo * c) as u64);
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        let mut a_conversions = 0u64;
        for i in 0..12u64 {
            a_conversions += cycle((i % 2) as u8, i);
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "{fidelity:?}: warm-arena frame cycle must not allocate"
        );
        assert_eq!(a_conversions, 12 * (ho * wo * c) as u64);
        assert!(arena.hit_rate() > 0.5, "warm arena should be mostly hits");
    }
}
