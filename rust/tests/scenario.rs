//! Integration: the deterministic scenario driver — shape-pure batching
//! over a heterogeneous fleet, camera churn (hot-add / clean removal /
//! mid-stream crash with producer restart / rate shifts), accepted-frame
//! conservation under crash storms, digest determinism, and
//! membership-independent camera seeding.  Needs no artifacts or PJRT.

use std::collections::BTreeMap;

use p2m::coordinator::{
    run_scenario, BatchClassifier, CameraReport, MeanThresholdClassifier, Metrics,
    Scenario, ScenarioReport, SegmentEnd, ShapeKey, WireFormat, WirePayload,
};

fn run(scenario: &Scenario) -> ScenarioReport {
    let mut clf = MeanThresholdClassifier::new(0.5);
    run_scenario(&mut clf, scenario, &Metrics::new()).unwrap()
}

/// The deterministic per-camera outcome tuple (timing excluded).
fn outcome(cam: &CameraReport) -> (u64, u32, u64, u64, u64, u64, u64) {
    (
        cam.spec.id,
        cam.incarnations,
        cam.scripted_frames,
        cam.stats.frames_captured,
        cam.stats.frames_classified,
        cam.stats.bytes_from_sensor,
        cam.stats.correct,
    )
}

/// Backend asserting every delivered batch is homogeneous in dims + wire
/// encoding, while counting frames per shape.
#[derive(Default)]
struct ShapeChecker {
    per_shape: BTreeMap<ShapeKey, u64>,
}

impl BatchClassifier for ShapeChecker {
    fn classify(&mut self, batch: &[&WirePayload]) -> anyhow::Result<Vec<u8>> {
        let shape = batch[0].shape_key();
        assert!(
            batch.iter().all(|p| p.shape_key() == shape),
            "shape-mixed batch delivered to the classifier"
        );
        *self.per_shape.entry(shape).or_default() += batch.len() as u64;
        Ok(vec![0; batch.len()])
    }
}

#[test]
fn mixed_res_scenario_serves_shape_pure_batches_end_to_end() {
    let scenario = Scenario::canned("mixed-res", 1).unwrap();
    let mut clf = ShapeChecker::default();
    let report = run_scenario(&mut clf, &scenario, &Metrics::new()).unwrap();

    // Three sensor designs -> three compiled plans (two 40px/q8 cameras
    // share one) and three shape groups, every batch shape-pure
    // (asserted inside the classifier above).
    assert_eq!(report.plans_compiled, 3);
    assert_eq!(report.per_shape.len(), 3);
    let expect = [
        ShapeKey { h: 8, w: 8, c: 8, bits: 8 },    // 2x 40px quantized-8
        ShapeKey { h: 4, w: 4, c: 8, bits: 6 },    // 20px quantized-6
        ShapeKey { h: 16, w: 16, c: 8, bits: 0 },  // 80px dense f32
    ];
    for shape in expect {
        assert!(report.per_shape.contains_key(&shape), "missing {shape}");
    }
    // The classifier's own per-shape view agrees with the report's.
    for (shape, ss) in &report.per_shape {
        assert_eq!(clf.per_shape[shape], ss.frames_classified, "{shape}");
    }

    // Lossless: every scripted frame was captured and classified.
    for cam in &report.per_camera {
        assert_eq!(cam.stats.frames_captured, cam.scripted_frames);
        assert_eq!(cam.stats.frames_classified, cam.scripted_frames);
        assert_eq!(cam.stats.frames_dropped, 0);
        assert_eq!(cam.incarnations, 1);
    }
    // Per-shape byte accounting is exact: q8 = 1 B/value, q6 packs
    // 6 bits/value, dense = 4 B/value.
    let q8 = &report.per_shape[&ShapeKey { h: 8, w: 8, c: 8, bits: 8 }];
    assert_eq!(q8.bytes_from_sensor, 20 * 8 * 8 * 8);
    let q6 = &report.per_shape[&ShapeKey { h: 4, w: 4, c: 8, bits: 6 }];
    assert_eq!(q6.bytes_from_sensor, 10 * (4 * 4 * 8 * 6u64).div_ceil(8));
    let dense = &report.per_shape[&ShapeKey { h: 16, w: 16, c: 8, bits: 0 }];
    assert_eq!(dense.bytes_from_sensor, 10 * 16 * 16 * 8 * 4);
}

#[test]
fn churn_scenario_is_deterministic_and_honours_the_script() {
    let scenario = Scenario::canned("churn", 33).unwrap();
    let a = run(&scenario);
    let b = run(&scenario);
    assert_eq!(a.digest(), b.digest(), "fixed seed must reproduce the digest");
    let tuples: Vec<_> = a.per_camera.iter().map(outcome).collect();
    assert_eq!(tuples, b.per_camera.iter().map(outcome).collect::<Vec<_>>());
    // (Seed *sensitivity* is pinned at payload level by the fleet's
    // camera_seeds_reach_the_scene_stream test — the digest folds stats
    // counters, which different seeds can legitimately coincide on.)

    // Script honoured: the crash-restart camera (id 3) ran twice; the
    // hot-add camera (id 2) still served everything it scripted; nobody
    // lost an accepted frame (Block backpressure).
    let by_id = |id: u64| a.per_camera.iter().find(|c| c.spec.id == id).unwrap();
    assert_eq!(by_id(3).incarnations, 2);
    assert_eq!(by_id(2).incarnations, 1);
    for cam in &a.per_camera {
        assert_eq!(cam.stats.frames_captured, cam.scripted_frames, "id {}", cam.spec.id);
        assert_eq!(
            cam.stats.frames_classified, cam.stats.frames_captured,
            "id {}: accepted frames must all be classified",
            cam.spec.id
        );
        assert_eq!(cam.stats.frames_dropped, 0);
    }
    // 40px/q8 is shared by cameras 0 and 2; dense 40px needs the same
    // plan; 20px/q8 and 20px/q4 are their own designs -> 3 plans.
    assert_eq!(a.plans_compiled, 3);
}

#[test]
fn crash_storm_loses_no_accepted_frames_and_restarts_every_producer() {
    let scenario = Scenario::canned("crash-storm", 5).unwrap();
    let metrics = Metrics::new();
    let mut clf = MeanThresholdClassifier::new(0.5);
    let report = run_scenario(&mut clf, &scenario, &metrics).unwrap();

    assert_eq!(report.per_camera.len(), 6);
    for cam in &report.per_camera {
        // Every camera's script is 3 incarnations (2 crashes + final).
        assert_eq!(cam.incarnations, 3, "id {}", cam.spec.id);
        assert_eq!(cam.scripted_frames, 10);
        // No accepted frame lost: captured == pushed == classified.
        assert_eq!(cam.stats.frames_captured, 10, "id {}", cam.spec.id);
        assert_eq!(cam.stats.frames_classified, 10, "id {}", cam.spec.id);
        assert_eq!(cam.stats.frames_dropped, 0);
    }
    assert_eq!(report.aggregate.frames_classified, 60);
    // 2 restarts per camera (the terminal crash of camera 5 restarts
    // nothing — its orphaned link is closed by the supervisor).
    assert_eq!(metrics.counter("scenario_producer_restarts").get(), 12);
    // Determinism holds across the storm too.
    assert_eq!(report.digest(), run(&scenario).digest());
}

#[test]
fn removing_a_camera_never_reseeds_the_survivors() {
    // The churn-reproducibility regression test at scenario level:
    // drop one camera from the script and every surviving camera's
    // deterministic outcome must be byte-for-byte unchanged.
    let full = Scenario::canned("churn", 77).unwrap();
    let mut shrunk = full.clone();
    let removed = shrunk.cameras.remove(1).spec.id;
    let a = run(&full);
    let b = run(&shrunk);
    assert_eq!(b.per_camera.len(), a.per_camera.len() - 1);
    for cam in &b.per_camera {
        assert_ne!(cam.spec.id, removed);
        let twin = a
            .per_camera
            .iter()
            .find(|c| c.spec.id == cam.spec.id)
            .expect("survivor present in the full run");
        assert_eq!(outcome(cam), outcome(twin), "id {}", cam.spec.id);
    }
}

#[test]
fn dense_and_quantized_scenarios_agree_per_camera() {
    // Flipping every camera's wire format is a pure link re-encoding:
    // identical per-camera decisions (ingest dequantisation is
    // bit-identical), different bytes.
    let base = Scenario::canned("mixed-res", 9).unwrap();
    let with_wire = |wire: WireFormat| {
        let mut s = base.clone();
        for cam in &mut s.cameras {
            cam.spec.wire = wire;
        }
        run(&s)
    };
    let dense = with_wire(WireFormat::Dense);
    let quant = with_wire(WireFormat::Quantized);
    for (d, q) in dense.per_camera.iter().zip(&quant.per_camera) {
        assert_eq!(d.spec.id, q.spec.id);
        assert_eq!(d.stats.frames_classified, q.stats.frames_classified);
        assert_eq!(
            d.stats.correct, q.stats.correct,
            "id {}: wire format must not change decisions",
            d.spec.id
        );
        assert!(
            q.stats.bytes_from_sensor < d.stats.bytes_from_sensor,
            "id {}: quantized wire must shrink the link",
            d.spec.id
        );
    }
}

#[test]
fn rate_limited_segments_only_pace_never_drop() {
    // The churn scenario's camera 4 shifts from 500 fps pacing to
    // free-running; pacing must never change counts or contents.
    let scenario = Scenario::canned("churn", 12).unwrap();
    let report = run(&scenario);
    let cam4 = report.per_camera.iter().find(|c| c.spec.id == 4).unwrap();
    assert_eq!(cam4.spec.wire, WireFormat::Dense);
    assert_eq!(cam4.incarnations, 1, "a rate shift is not a lifecycle event");
    assert_eq!(cam4.stats.frames_classified, cam4.scripted_frames);
}

#[test]
fn unknown_and_malformed_scenarios_are_rejected() {
    assert!(Scenario::canned("nope", 0).is_none());
    // An empty scenario fails validation inside run_scenario.
    let empty = Scenario::new("empty", 0, vec![]);
    let mut clf = MeanThresholdClassifier::new(0.5);
    assert!(run_scenario(&mut clf, &empty, &Metrics::new()).is_err());
}

#[test]
fn segment_end_variants_are_exported() {
    // Public API sanity for downstream script builders.
    assert_ne!(SegmentEnd::Shift, SegmentEnd::Crash);
    assert_ne!(SegmentEnd::Crash, SegmentEnd::Clean);
}
