//! Integration: the fixed producer pool at fleet scale — worker-count
//! invariance of scenario digests (the same script must hash identically
//! whether 1 or 8 pool workers realise it), digest pinning against a
//! committed fixture with first-run bootstrap, and starvation-freedom
//! when one high-rate camera shares the pool with a paced swarm.
//! Needs no artifacts or PJRT.

use std::collections::BTreeMap;
use std::path::PathBuf;

use p2m::coordinator::{
    run_scenario, CameraReport, CameraScript, CameraSpec, MeanThresholdClassifier,
    Metrics, Scenario, ScenarioReport, Segment, SegmentEnd, WireFormat,
};
use p2m::util::json::Json;

fn run_with_pool(scenario: &Scenario, workers: usize) -> (ScenarioReport, Metrics) {
    let mut s = scenario.clone();
    s.pool_workers = Some(workers);
    let metrics = Metrics::new();
    let mut clf = MeanThresholdClassifier::new(0.5);
    let report = run_scenario(&mut clf, &s, &metrics).unwrap();
    (report, metrics)
}

/// The deterministic per-camera outcome tuple (timing excluded) — the
/// fields the digest folds, compared structurally for better failure
/// messages than a hash mismatch.
fn outcome(cam: &CameraReport) -> (u64, u32, u64, u64, u64, u64, u64, u64) {
    (
        cam.spec.id,
        cam.incarnations,
        cam.scripted_frames,
        cam.stats.frames_captured,
        cam.stats.frames_classified,
        cam.stats.frames_dropped,
        cam.stats.bytes_from_sensor,
        cam.stats.correct,
    )
}

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/scenario_digests.json")
}

/// Compare the computed digests against the committed fixture.  The
/// fixture ships un-armed (no pinned values): the first run on a real
/// toolchain arms it with the digests just computed — which the caller
/// has already cross-checked across worker counts and repeat runs — and
/// every later run compares strictly.  A drift after arming means the
/// refactor changed observable outcomes, not just scheduling.
fn check_fixture(digests: &BTreeMap<String, u64>) {
    let path = fixture_path();
    let text = std::fs::read_to_string(&path)
        .expect("tests/fixtures/scenario_digests.json must be checked in");
    let v = Json::parse(&text).expect("digest fixture parses");
    assert_eq!(
        v.get("schema").and_then(Json::as_str),
        Some("p2m-scenario-digests-v1"),
        "unknown digest fixture schema"
    );
    if v.get("armed").and_then(Json::as_bool) == Some(true) {
        let pinned = v.get("digests").and_then(Json::as_obj).expect("armed fixture has digests");
        for (label, digest) in digests {
            let want = pinned
                .get(label)
                .and_then(Json::as_str)
                .unwrap_or_else(|| panic!("fixture has no pinned digest for '{label}'"));
            assert_eq!(
                format!("{digest:016x}"),
                want,
                "'{label}' digest drifted from the pinned fixture; if the \
                 behaviour change is intentional, set \"armed\": false and \
                 empty \"digests\" in scenario_digests.json, rerun to \
                 re-bootstrap, and commit the re-armed file"
            );
        }
    } else {
        let pinned: BTreeMap<String, Json> = digests
            .iter()
            .map(|(k, &d)| (k.clone(), Json::Str(format!("{d:016x}"))))
            .collect();
        let out = Json::obj(vec![
            ("schema", Json::Str("p2m-scenario-digests-v1".into())),
            ("armed", Json::Bool(true)),
            ("digests", Json::Obj(pinned)),
        ]);
        std::fs::write(&path, out.dump() + "\n").expect("write armed digest fixture");
        eprintln!(
            "scenario_digests.json was un-armed: pinned {} digests — \
             commit the armed fixture so future runs compare against it",
            digests.len()
        );
    }
}

#[test]
fn digests_are_invariant_across_pool_worker_counts() {
    // The tentpole's determinism contract: camera state lives in cells,
    // workers only lend CPU — so 1, 2, 4 and 8 pool workers must realise
    // byte-identical outcomes for every scripted scenario, swarm scale
    // included (reduced to 192 cameras to keep the matrix quick).
    let scenarios: Vec<(&str, Scenario)> = vec![
        ("swarm-192", Scenario::swarm(192, 11)),
        ("churn", Scenario::canned("churn", 11).unwrap()),
        ("crash-storm", Scenario::canned("crash-storm", 11).unwrap()),
        ("static-scene", Scenario::canned("static-scene", 11).unwrap()),
        ("detect-track", Scenario::canned("detect-track", 11).unwrap()),
    ];
    let mut digests: BTreeMap<String, u64> = BTreeMap::new();
    for (label, scenario) in &scenarios {
        let (base, _) = run_with_pool(scenario, 1);
        if *label == "static-scene" {
            // The sparse path's own contract rides the same matrix: every
            // frame shipped as events, and a frozen scene collapses the
            // wire to under 1% of its dense-ladder equivalent.
            assert_eq!(base.events.event_frames, base.aggregate.frames_classified);
            assert!(
                base.events.wire_bytes * 100 < base.events.dense_equiv_bytes,
                "static scene wire bytes {} are not <1% of dense {}",
                base.events.wire_bytes,
                base.events.dense_equiv_bytes
            );
        }
        if *label == "detect-track" {
            // The detect workload's contract rides the matrix too: every
            // classified frame was tracked, the detection count splits
            // exactly into associations + new tracks, and each scripted
            // crash (cam1 once, cam2 twice) resynced the tracker.
            assert_eq!(base.track.frames_tracked, base.aggregate.frames_classified);
            assert_eq!(
                base.track.detections,
                base.track.associations + base.track.tracks_started
            );
            assert_eq!(base.track.resyncs, 3, "scripted crashes must resync the tracker");
        }
        let base_outcomes: Vec<_> = base.per_camera.iter().map(outcome).collect();
        for workers in [2usize, 4, 8] {
            let (r, _) = run_with_pool(scenario, workers);
            let got: Vec<_> = r.per_camera.iter().map(outcome).collect();
            assert_eq!(got, base_outcomes, "{label}: {workers} workers changed an outcome");
            assert_eq!(
                r.digest(),
                base.digest(),
                "{label}: {workers} workers changed the digest"
            );
        }
        // Repeatability at a fixed worker count (same contract the CI
        // swarm smoke checks via --check-digest).
        let (again, _) = run_with_pool(scenario, 4);
        assert_eq!(again.digest(), base.digest(), "{label}: rerun drifted");
        digests.insert((*label).to_string(), base.digest());
    }
    check_fixture(&digests);
}

#[test]
fn swarm_completes_on_a_bounded_pool_without_losing_frames() {
    // 512 cameras over at most 4 worker threads: every scripted frame is
    // captured and classified, nothing drops (Block backpressure), and
    // the scheduler actually ran the timer wheel.
    let (report, metrics) = run_with_pool(&Scenario::swarm(512, 3), 4);
    assert_eq!(report.per_camera.len(), 512);
    for cam in &report.per_camera {
        assert_eq!(cam.incarnations, 1, "id {}", cam.spec.id);
        assert_eq!(cam.scripted_frames, 2);
        assert_eq!(cam.stats.frames_captured, 2, "id {}", cam.spec.id);
        assert_eq!(cam.stats.frames_classified, 2, "id {}", cam.spec.id);
        assert_eq!(cam.stats.frames_dropped, 0);
    }
    assert_eq!(report.aggregate.frames_classified, 1024);
    // One design -> one compiled plan and one shape group, however many
    // cameras share it.
    assert_eq!(report.plans_compiled, 1);
    assert_eq!(report.per_shape.len(), 1);
    assert_eq!(metrics.counter("scenario_frames_captured").get(), 1024);
    // The pool's own instruments: the dispatch backlog peaked above zero
    // (512 ready cells cannot all be in flight on 4 workers)...
    assert!(
        metrics.gauge("pool_queue_depth").high_watermark() > 0,
        "dispatch backlog never observed above zero"
    );
    // ...and the lag watermark is a sane microsecond reading.
    assert!(metrics.gauge("timer_lag_max_us").high_watermark() >= 0);
}

#[test]
fn a_high_rate_camera_cannot_starve_the_paced_swarm() {
    // 256 paced cameras (400 fps — a 25-tick wheel period) plus one
    // free-running hog streaming 128 frames as fast as the pool lets it.
    // Starvation-freedom here is exact, not statistical: the run only
    // ends when every script completes, so a flatlined camera would hang
    // the test, and the burst budget bounds how long the hog can pin a
    // worker between other cameras' fires.
    let mut scenario = Scenario::swarm(256, 9);
    for cam in &mut scenario.cameras {
        cam.segments = vec![Segment::paced(2, 400.0, SegmentEnd::Clean)];
    }
    scenario.cameras.push(CameraScript {
        spec: CameraSpec::new(256, 20, 8, WireFormat::Quantized),
        start_delay: std::time::Duration::ZERO,
        segments: vec![Segment::free(128, SegmentEnd::Clean)],
    });
    scenario.name = "swarm-hog".into();

    let (report, metrics) = run_with_pool(&scenario, 4);
    assert_eq!(report.per_camera.len(), 257);
    for cam in &report.per_camera {
        assert_eq!(
            cam.stats.frames_captured, cam.scripted_frames,
            "id {} flatlined",
            cam.spec.id
        );
        assert_eq!(cam.stats.frames_classified, cam.stats.frames_captured);
        assert_eq!(cam.stats.frames_dropped, 0);
    }
    let hog = report.per_camera.iter().find(|c| c.spec.id == 256).unwrap();
    assert_eq!(hog.stats.frames_classified, 128);
    assert_eq!(report.aggregate.frames_classified, 256 * 2 + 128);
    // Pacing is real: 400 fps cameras spread over >= 25 wheel ticks, so
    // the scheduler must have advanced the wheel.
    assert!(
        metrics.counter("scheduler_ticks").get() >= 25,
        "wheel barely advanced: {} ticks",
        metrics.counter("scheduler_ticks").get()
    );
    // And the paced swarm's digest is still worker-count invariant with
    // the hog in the mix.
    let (one_worker, _) = run_with_pool(&scenario, 1);
    assert_eq!(one_worker.digest(), report.digest());
}
