//! Integration: the paper's headline claims, checked end-to-end through
//! the public API (the same code paths the `p2m` CLI prints).

use p2m::compression;
use p2m::config::HyperParams;
use p2m::energy::{DelayConstants, EnergyConstants, PipelineKind, PipelineModel};
use p2m::model::{table2_rows, ArchConfig};

#[test]
fn headline_bandwidth_reduction() {
    // Section 4.3: Eq. 2 with Table 1 values (paper quotes ~21x; the
    // formula evaluates to 18.75x — see EXPERIMENTS.md).
    let h = HyperParams::default();
    let br = compression::bandwidth_reduction(&h, 560, 12);
    assert!((br - 18.75).abs() < 1e-9);
}

#[test]
fn headline_energy_delay_edp() {
    let p2m = PipelineModel::from_paper_reported(PipelineKind::P2m);
    let base = PipelineModel::from_paper_reported(PipelineKind::BaselineCompressed);
    let e = EnergyConstants::default();
    let d = DelayConstants::default();

    let energy_ratio = base.energy(&e).total() / p2m.energy(&e).total();
    let delay_ratio = base.delay(&d).total_sequential() / p2m.delay(&d).total_sequential();
    let edp_seq = base.edp(&e, &d, true) / p2m.edp(&e, &d, true);
    let edp_overlap = base.edp(&e, &d, false) / p2m.edp(&e, &d, false);

    // Paper Section 5.3: up to 7.81x energy, 2.15x delay, 16.76x EDP
    // (sequential), ~11x (conservative overlap).
    assert!((6.5..9.5).contains(&energy_ratio), "energy {energy_ratio}");
    assert!((1.8..2.8).contains(&delay_ratio), "delay {delay_ratio}");
    assert!((13.0..23.0).contains(&edp_seq), "edp seq {edp_seq}");
    assert!((9.0..16.0).contains(&edp_overlap), "edp overlap {edp_overlap}");
    // Orderings the paper's Fig. 8 shows.
    assert!(edp_seq > edp_overlap);
    assert!(energy_ratio > delay_ratio);
}

#[test]
fn table2_shape_holds_at_all_resolutions() {
    // P2M custom always beats baseline on MAdds and peak memory, at
    // every resolution the paper evaluates.
    let rows = table2_rows();
    for &res in &[560usize, 225, 115] {
        let b = rows.iter().find(|r| r.resolution == res && r.model == "baseline").unwrap();
        let c = rows.iter().find(|r| r.resolution == res && r.model == "p2m_custom").unwrap();
        assert!(c.madds_g < b.madds_g, "res {res}");
        assert!(c.peak_memory_mb < b.peak_memory_mb, "res {res}");
    }
    // Both columns shrink with resolution.
    let madds: Vec<f64> = [560, 225, 115]
        .iter()
        .map(|&r| rows.iter().find(|x| x.resolution == r && x.model == "baseline").unwrap().madds_g)
        .collect();
    assert!(madds[0] > madds[1] && madds[1] > madds[2]);
}

#[test]
fn p2m_fits_tinyml_budget() {
    // Section 5.2: "our P2M model can run on tiny micro-controllers with
    // only 270 KB of on-chip SRAM" — peak activation memory must fit.
    let m = p2m::model::analyse(&ArchConfig::paper_p2m(560));
    assert!(m.peak_memory_bytes <= 310_000, "{}", m.peak_memory_bytes);
    let b = p2m::model::analyse(&ArchConfig::paper_baseline(560));
    assert!(b.peak_memory_bytes > 2_000_000, "baseline must NOT fit");
}

#[test]
fn fig8_normalised_components() {
    // Fig. 8a: for the baseline, SoC (MAC) energy dominates sensing; for
    // P2M both shrink and communication is a visible slice.
    let e = EnergyConstants::default();
    let base = PipelineModel::from_paper_reported(PipelineKind::BaselineCompressed);
    let bb = base.energy(&e);
    assert!(bb.e_mac > bb.e_sens);
    let p2m = PipelineModel::from_paper_reported(PipelineKind::P2m);
    let pb = p2m.energy(&e);
    assert!(pb.e_sens < bb.e_sens / 10.0);
    assert!(pb.e_com < bb.e_com / 5.0);
}
