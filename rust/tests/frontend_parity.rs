//! THE cross-language contract test: the rust analog frontend in
//! functional mode must reproduce the JAX/Pallas golden model (the
//! exported `frontend_*.hlo.txt`) code-for-code, up to quantisation-
//! boundary flips from float reassociation.
//!
//! This is what makes the circuit simulator trustworthy: the same
//! weights, the same curve-fit surface, two independent implementations.

use std::collections::BTreeMap;

use p2m::analog::TransferSurface;
use p2m::config::SystemConfig;
use p2m::frontend::{Fidelity, FramePlan};
use p2m::runtime::{Manifest, ModelBundle, Runtime, Tensor};
use p2m::sensor::{Image, SceneGen, Split};

fn artifacts_built() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

fn build_plan(bundle: &ModelBundle, fidelity: Fidelity) -> FramePlan {
    let sp = bundle.stem_params().unwrap();
    let (scale, shift) = sp.fused_bn();
    FramePlan::build(
        SystemConfig::for_resolution(bundle.entry.resolution),
        &sp.theta,
        scale,
        shift,
        TransferSurface::load_default(),
        fidelity,
    )
    .unwrap()
}

fn run_cases(res: usize, n_images: usize) {
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, res).unwrap();
    let engine = build_plan(&bundle, Fidelity::Functional);
    let mut ctx = engine.ctx();
    let lsb = engine.cfg.adc.lsb() as f32;
    let gen = SceneGen::new(res, 1234);
    let artifact = format!("frontend_{res}_b1");

    let mut total = 0usize;
    let mut sum_dev_lsb = 0.0f64;
    for i in 0..n_images {
        let img = gen.image((i % 2) as u8, i as u64, Split::Test);
        // JAX path
        let mut extra = BTreeMap::new();
        extra.insert(
            "image",
            Tensor::f32(vec![1, res, res, 3], img.data.clone()),
        );
        let jax_out = bundle.run(&artifact, &extra).unwrap().remove(0);
        let jax = jax_out.as_f32().unwrap();
        // rust analog path
        let (acts, _) = engine.process(&Image::from_vec(res, res, 3, img.data.clone()), &mut ctx);
        assert_eq!(acts.data.len(), jax.len());
        for (r, j) in acts.data.iter().zip(jax) {
            let d = (r - j).abs();
            // Hard bound: never more than one code apart.  Synthetic
            // scenes have large *flat* regions whose shared pre-quant
            // value can sit exactly on a code boundary, so whole regions
            // legitimately flip together between f32 (JAX) and f64
            // (rust) accumulation — exact-match fractions are therefore
            // brittle; the meaningful contract is the 1-LSB bound plus a
            // small mean deviation.
            assert!(
                d <= lsb * 1.001,
                "rust {r} vs jax {j} differ by {d} (> 1 LSB) at res {res}"
            );
            total += 1;
            sum_dev_lsb += (d / lsb) as f64;
        }
    }
    let mean_dev = sum_dev_lsb / total as f64;
    assert!(mean_dev <= 0.30, "mean deviation {mean_dev:.4} LSB too high");
}

#[test]
fn rust_frontend_matches_jax_at_80() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    run_cases(80, 3);
}

#[test]
fn rust_frontend_matches_jax_at_120() {
    if !artifacts_built() {
        return;
    }
    run_cases(120, 2);
}

#[test]
fn event_accurate_close_to_jax() {
    // The circuit-accurate path deviates only by per-phase quantisation
    // (bounded by ~2 LSB) — measured against the JAX golden model.
    if !artifacts_built() {
        return;
    }
    let res = 80;
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, res).unwrap();
    let engine = build_plan(&bundle, Fidelity::EventAccurate);
    let lsb = engine.cfg.adc.lsb() as f32;
    let gen = SceneGen::new(res, 99);
    let img = gen.image(1, 0, Split::Test);

    let mut extra = BTreeMap::new();
    extra.insert("image", Tensor::f32(vec![1, res, res, 3], img.data.clone()));
    let jax_out = bundle.run("frontend_80_b1", &extra).unwrap().remove(0);
    let jax = jax_out.as_f32().unwrap();
    let (acts, report) = engine.process_once(&Image::from_vec(res, res, 3, img.data.clone()));
    assert_eq!(report.saturated_phases, 0, "init weights must fit the window");
    for (r, j) in acts.data.iter().zip(jax) {
        assert!((r - j).abs() <= 2.5 * lsb, "event {r} vs jax {j}");
    }
}
