//! SIMD-vs-scalar bit-identity property suite (DESIGN.md §3.7).
//!
//! The dispatch seam's contract is that every runtime-selected tier
//! reproduces the scalar reference kernels **bit for bit** — the
//! serial-vs-parallel and cross-`P2M_SIMD` digest-invariance guarantees
//! rest on it.  This binary sweeps every tier the build supports
//! (`supported_tiers`, scalar first) against the scalar kernels over
//! randomized shapes that straddle lane counts, register-block widths
//! and the KC cache panel, plus adversarial value sets for the
//! quantiser.  Run it under `P2M_SIMD=off` too (CI does) to confirm the
//! suite passes when dispatch is pinned to scalar.

use p2m::util::rng::Rng;
use p2m::util::simd::{
    self, matmul_f64_scalar, matmul_i32_scalar, quantize_codes_scalar, supported_tiers, KC,
};

/// Shapes chosen to straddle every vector boundary: n sweeps ragged
/// tails around the 2/4/8-lane widths, k crosses the KC panel edge, m
/// exercises the row loop.
fn gemm_shapes() -> Vec<(usize, usize, usize)> {
    let mut shapes = Vec::new();
    for n in [1usize, 2, 3, 4, 5, 7, 8, 9, 12, 13, 16, 17] {
        shapes.push((3, 10, n));
    }
    for k in [1usize, KC - 1, KC, KC + 1, KC + 9, 2 * KC + 3] {
        shapes.push((2, k, 11));
    }
    shapes.push((1, 1, 1));
    shapes.push((7, 37, 19));
    shapes
}

#[test]
fn matmul_f64_is_bit_identical_on_every_tier() {
    let mut rng = Rng::seed(0xF64);
    for (m, k, n) in gemm_shapes() {
        let a: Vec<f64> = (0..m * k).map(|_| rng.range(-3.0, 3.0)).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.range(-3.0, 3.0)).collect();
        let mut want = vec![0.0f64; m * n];
        matmul_f64_scalar(m, k, n, &a, &b, &mut want);
        for tier in supported_tiers() {
            // Dirty output: the kernels must overwrite, not accumulate.
            let mut got = vec![99.0f64; m * n];
            simd::matmul_f64(tier, m, k, n, &a, &b, &mut got);
            // Bit-level comparison: -0.0 != +0.0 would slip through ==.
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got), bits(&want), "tier {tier} shape {m}x{k}x{n}");
        }
    }
}

#[test]
fn matmul_i32_is_exact_on_every_tier() {
    let mut rng = Rng::seed(0x132);
    for (m, k, n) in gemm_shapes() {
        let a: Vec<i32> = (0..m * k).map(|_| rng.i64(-7, 8) as i32).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rng.i64(0, 256) as i32).collect();
        let mut want = vec![0i32; m * n];
        matmul_i32_scalar(m, k, n, &a, &b, &mut want);
        for tier in supported_tiers() {
            let mut got = vec![-5i32; m * n];
            simd::matmul_i32(tier, m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "tier {tier} shape {m}x{k}x{n}");
        }
    }
}

/// Values that break naive vector rounding: exact halves (round-half-
/// away vs the FPU's half-even), the largest f64 below 0.5, huge and
/// non-finite values (saturating `as i64` casts), signed zeros and
/// subnormals.
fn adversarial_values() -> Vec<f32> {
    let mut v = vec![
        0.0,
        -0.0,
        0.5,
        -0.5,
        1.5,
        2.5,
        -2.5,
        0.499_999_97,
        0.500_000_03,
        127.5,
        128.5,
        254.5,
        255.49,
        1.0e30,
        -1.0e30,
        1.0e-40,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
        f32::MAX,
        f32::MIN,
    ];
    let mut rng = Rng::seed(0x0ADC);
    for _ in 0..200 {
        v.push(rng.range(-2.0, 300.0) as f32);
    }
    v
}

#[test]
fn quantize_codes_matches_scalar_on_every_tier() {
    let values = adversarial_values();
    // Sweep lengths too, so vector tails see the adversarial values.
    for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, values.len()] {
        let vals = &values[..len.min(values.len())];
        for &(scale, zp, code_max) in
            &[(0.5f64, 1i64, 255u32), (1.0, 0, 1), (75.0 / 255.0, 0, 255), (1e-3, 128, 65535)]
        {
            let mut want = Vec::new();
            let want_clamped =
                quantize_codes_scalar(vals, scale, zp, code_max, |i, c| want.push((i, c)));
            for tier in supported_tiers() {
                let mut got = Vec::new();
                let clamped =
                    simd::quantize_codes(tier, vals, scale, zp, code_max, |i, c| {
                        got.push((i, c))
                    });
                assert_eq!(got, want, "tier {tier} len {len} scale {scale}");
                assert_eq!(clamped, want_clamped, "tier {tier} len {len} scale {scale}");
            }
        }
    }
}

#[test]
fn pack_unpack_match_the_bit_reference_on_every_tier() {
    let mut rng = Rng::seed(0xBEEF);
    for bits in 1..=16u32 {
        // Ragged lengths around byte and word boundaries of the packed
        // stream (65 values of 7 bits = 455 bits = 56.875 bytes, etc).
        for len in [0usize, 1, 2, 7, 8, 9, 63, 64, 65, 200] {
            let max = (1u64 << bits) - 1;
            let packed_len = (len * bits as usize).div_ceil(8);
            if bits <= 8 {
                let codes: Vec<u8> =
                    (0..len).map(|_| (rng.i64(0, max as i64 + 1)) as u8).collect();
                let mut want = vec![0u8; packed_len];
                simd::pack_codes_u8(simd::SimdTier::Scalar, &codes, bits, &mut want);
                for tier in supported_tiers() {
                    // Packers require zero-filled output (the scalar
                    // reference ORs bits in); unpack outputs are dirty.
                    let mut got = vec![0u8; packed_len];
                    simd::pack_codes_u8(tier, &codes, bits, &mut got);
                    assert_eq!(got, want, "pack u8 tier {tier} bits {bits} len {len}");
                    let mut back = vec![0xFFu8; len];
                    simd::unpack_codes_u8(tier, &got, bits, &mut back);
                    assert_eq!(back, codes, "unpack u8 tier {tier} bits {bits} len {len}");
                }
            } else {
                let codes: Vec<u16> =
                    (0..len).map(|_| (rng.i64(0, max as i64 + 1)) as u16).collect();
                let mut want = vec![0u8; packed_len];
                simd::pack_codes_u16(simd::SimdTier::Scalar, &codes, bits, &mut want);
                for tier in supported_tiers() {
                    let mut got = vec![0u8; packed_len];
                    simd::pack_codes_u16(tier, &codes, bits, &mut got);
                    assert_eq!(got, want, "pack u16 tier {tier} bits {bits} len {len}");
                    let mut back = vec![0xFFFFu16; len];
                    simd::unpack_codes_u16(tier, &got, bits, &mut back);
                    assert_eq!(back, codes, "unpack u16 tier {tier} bits {bits} len {len}");
                }
            }
        }
    }
}

#[test]
fn active_tier_honours_the_env_override() {
    // The test binary may or may not inherit P2M_SIMD; either way the
    // active tier must be one the build supports, and pinning via env
    // must resolve to scalar when CI sets P2M_SIMD=off.
    let tier = simd::active_tier();
    assert!(supported_tiers().contains(&tier));
    if std::env::var("P2M_SIMD").as_deref() == Ok("off") {
        assert_eq!(tier, simd::SimdTier::Scalar);
    }
}
