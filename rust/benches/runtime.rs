//! Bench: PJRT runtime — artifact compile time and per-call execution
//! latency for every serving graph (the SoC side of the Fig. 8 delay).

use std::collections::BTreeMap;

use p2m::runtime::{Manifest, ModelBundle, Runtime, Tensor};
use p2m::util::bench::Bench;
use p2m::util::rng::Rng;

fn rand_tensor(dims: &[usize], seed: u64) -> Tensor {
    let mut rng = Rng::seed(seed);
    let n: usize = dims.iter().product();
    Tensor::f32(dims.to_vec(), (0..n).map(|_| rng.f32()).collect())
}

fn main() {
    if !Manifest::default_dir().join("manifest.json").exists() {
        println!("(runtime bench skipped: run `make artifacts`)");
        return;
    }
    let mut b = Bench::new("runtime");
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();

    // Compile times (one-off costs, measured once each).
    for name in ["frontend_80_b1", "backbone_80_b1", "full_80_b1", "backbone_80_b8"] {
        let t0 = std::time::Instant::now();
        bundle.executable(name).unwrap();
        println!("compile {name:<32} {:>10.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    }

    let img1 = rand_tensor(&[1, 80, 80, 3], 1);
    let img8 = rand_tensor(&[8, 80, 80, 3], 2);
    let acts1 = rand_tensor(&[1, 16, 16, 8], 3);
    let acts8 = rand_tensor(&[8, 16, 16, 8], 4);

    let mut extra = BTreeMap::new();
    extra.insert("image", img1.clone());
    b.run("frontend_80_b1 (pallas golden model)", || {
        bundle.run("frontend_80_b1", &extra).unwrap()
    });

    let mut extra = BTreeMap::new();
    extra.insert("acts", acts1);
    b.run("backbone_80_b1", || bundle.run("backbone_80_b1", &extra).unwrap());

    let mut extra = BTreeMap::new();
    extra.insert("acts", acts8);
    let per_frame = b.run("backbone_80_b8", || bundle.run("backbone_80_b8", &extra).unwrap());
    println!("  (batch-8 amortised: {:.2} ms/frame)", per_frame / 8.0 / 1e6);

    let mut extra = BTreeMap::new();
    extra.insert("image", img1);
    b.run("full_80_b1", || bundle.run("full_80_b1", &extra).unwrap());

    let mut extra = BTreeMap::new();
    extra.insert("image", img8);
    b.run("full_80_b8", || bundle.run("full_80_b8", &extra).unwrap());

    // Training step (the E2E driver's inner loop).
    let x = rand_tensor(&[16, 80, 80, 3], 5);
    let y = Tensor::i32(vec![16], (0..16).map(|i| i % 2).collect());
    b.run("train_step_80 (fwd+bwd+sgd b16)", || {
        bundle.train_step(x.clone(), y.clone(), 0.01).unwrap()
    });
}
