//! Bench: analog substrate — device DC solves vs. the curve-fit surface
//! (the Fig. 3 workload), and the weight-bank construction.
//!
//! The transfer-surface evaluation is the innermost op of the frontend
//! hot path: one call per (pixel, channel, rail) per receptive field.

use p2m::analog::{DeviceParams, TransferSurface, VariationModel, WeightBank};
use p2m::util::bench::{bb, Bench};
use p2m::util::rng::Rng;

fn main() {
    let mut b = Bench::new("analog");
    let p = DeviceParams::default();

    b.run("device_dc_solve (one pixel op point)", || {
        p2m::analog::pixel_output_voltage(&p, bb(0.6), bb(0.7))
    });

    let poly = TransferSurface::load_default();
    let device = TransferSurface::device_fallback();
    b.run("transfer_poly_eval", || poly.eval(bb(0.6), bb(0.7)));
    b.run("transfer_device_eval", || device.eval(bb(0.6), bb(0.7)));

    // Fig. 3 grid regeneration.
    b.run("fig3_grid_9x9 (device)", || {
        p2m::analog::device::sample_grid(&p, 9, 9)
    });

    // A full receptive field through the poly surface (75 x 8 x 2 evals).
    let mut rng = Rng::seed(1);
    let theta: Vec<f32> = (0..75 * 8).map(|_| rng.range(-1.0, 1.0) as f32).collect();
    let bank = WeightBank::from_theta(&theta, 75, 8, None);
    let patch: Vec<f64> = (0..75).map(|_| rng.f64()).collect();
    b.run("patch_x_8ch_poly (1200 evals)", || {
        let mut acc = 0.0;
        for c in 0..8 {
            for (pp, &x) in patch.iter().enumerate() {
                let w = bank.get(pp, c);
                acc += poly.eval(w.pos, x) - poly.eval(w.neg, x);
            }
        }
        acc
    });

    b.run("weight_bank_build_75x8", || {
        WeightBank::from_theta(bb(&theta), 75, 8, Some(8))
    });

    b.run("mismatch_sample_75x8x2", || {
        let vm = VariationModel::default();
        let mut rng = Rng::seed(7);
        let mut acc = 0.0;
        for _ in 0..75 * 8 * 2 {
            acc += vm.sample(&mut rng).width_mult;
        }
        acc
    });
}
