//! Bench: SS-ADC / CDS conversion paths (Fig. 4 workload).
//!
//! The paper's ADC story: a CDS double conversion costs 2 x 2^N counter
//! cycles of *circuit* time; here we measure the *simulation* cost of the
//! functional vs. event-accurate paths — the event path is the frontend's
//! fidelity knob.

use p2m::adc::{SsAdc, WaveformTrace};
use p2m::config::AdcConfig;
use p2m::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new("adc");
    let adc = SsAdc::new(AdcConfig::default());
    let lsb = adc.cfg.lsb();

    b.run("functional_quantize", || adc.quantize(bb(17.3 * lsb)));
    b.run("functional_shifted_relu", || adc.shifted_relu(bb(12.0 * lsb), 1.1, 2.0 * lsb));
    b.run("event_convert (256-step ramp)", || adc.convert_event(bb(17.3 * lsb), None));
    b.run("event_cds (512 cycles)", || {
        adc.convert_cds(bb(23.0 * lsb), bb(9.0 * lsb), 1.0, 4.0 * lsb, None)
    });
    b.run("event_cds_traced", || {
        let mut tr = WaveformTrace::new(4096);
        adc.convert_cds(bb(23.0 * lsb), bb(9.0 * lsb), 1.0, 4.0 * lsb, Some(&mut tr))
    });

    // One frame's worth of conversions at 80x80 (16*16*8 CDS ops).
    b.run("frame_80_conversions_functional", || {
        let mut acc = 0u32;
        for i in 0..16 * 16 * 8 {
            acc = acc.wrapping_add(adc.shifted_relu((i % 70) as f64 * lsb, 1.0, 0.0));
        }
        acc
    });
    b.run("frame_80_conversions_event", || {
        let mut acc = 0u64;
        for i in 0..16 * 16 * 8 {
            acc = acc.wrapping_add(
                adc.convert_cds((i % 70) as f64 * lsb, ((i / 3) % 50) as f64 * lsb, 1.0, 0.0, None)
                    .code as u64,
            );
        }
        acc
    });
}
