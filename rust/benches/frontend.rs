//! Bench: the in-pixel frontend — the L3 hot path (one call per captured
//! frame).  Functional GEMM route vs the per-patch folded route vs the
//! unfolded reference, event-accurate fidelity, plus the capture + scene
//! substrate it feeds on.  Contexts are reused across iterations, so the
//! rows measure the steady state (no per-frame allocation beyond the
//! output image).

use p2m::analog::TransferSurface;
use p2m::config::{SensorConfig, SystemConfig};
use p2m::frontend::{Fidelity, FramePlan};
use p2m::sensor::{expose, Camera, SceneGen, Split};
use p2m::util::bench::Bench;
use p2m::util::rng::Rng;

fn plan(res: usize, fidelity: Fidelity) -> FramePlan {
    let cfg = SystemConfig::for_resolution(res);
    let p = cfg.hyper.patch_len();
    let c = cfg.hyper.out_channels;
    let mut rng = Rng::seed(3);
    let theta: Vec<f32> = (0..p * c).map(|_| rng.range(-0.8, 0.8) as f32).collect();
    FramePlan::build(
        cfg,
        &theta,
        vec![1.0; c],
        vec![0.5; c],
        TransferSurface::load_default(),
        fidelity,
    )
    .unwrap()
}

fn main() {
    let mut b = Bench::new("frontend");

    let gen = SceneGen::new(80, 5);
    b.run("scene_gen_80", || gen.image(1, 3, Split::Train));

    let scene = gen.image(1, 0, Split::Train);
    let cfg = SensorConfig::default().with_resolution(80);
    let mut rng = Rng::seed(9);
    b.run("photodiode_expose_80", || expose(&cfg, &scene, &mut rng));

    let mut cam = Camera::new(cfg, 1, Split::Train);
    b.run("camera_capture_80 (scene+expose)", || cam.capture());

    let frame = Camera::new(cfg, 2, Split::Train).capture();
    for res in [80usize, 120] {
        let frame = if res == 80 {
            frame.image.clone()
        } else {
            Camera::new(SensorConfig::default().with_resolution(res), 2, Split::Train)
                .capture()
                .image
        };
        let func = plan(res, Fidelity::Functional);
        let n_out = {
            let (ho, wo, c) = func.cfg.out_dims();
            (ho * wo * c) as u64
        };
        let mut ctx = func.ctx();
        b.run_throughput(&format!("frontend_functional_{res}_gemm"), n_out, || {
            func.process(&frame, &mut ctx)
        });
        // §Perf before/after 2: the same fold driven per patch (the
        // pre-GEMM hot path).
        let per_patch = plan(res, Fidelity::Functional).with_gemm_disabled();
        let mut ctx = per_patch.ctx();
        b.run_throughput(&format!("frontend_functional_{res}_per_patch"), n_out, || {
            per_patch.process(&frame, &mut ctx)
        });
        // §Perf before/after 1: no fold at all (per-eval reference path).
        let slow = plan(res, Fidelity::Functional).with_fold_disabled();
        let mut ctx = slow.ctx();
        b.run_throughput(&format!("frontend_functional_{res}_unfolded"), n_out, || {
            slow.process(&frame, &mut ctx)
        });
        let ev = plan(res, Fidelity::EventAccurate);
        let mut ctx = ev.ctx();
        b.run_throughput(&format!("frontend_event_{res}"), n_out, || {
            ev.process(&frame, &mut ctx)
        });
    }
}
