//! Bench: the in-pixel frontend engine — the L3 hot path (one call per
//! captured frame).  Functional vs. event-accurate fidelity, plus the
//! capture + scene substrate it feeds on.

use p2m::analog::TransferSurface;
use p2m::config::{SensorConfig, SystemConfig};
use p2m::frontend::{Fidelity, FrontendEngine};
use p2m::sensor::{expose, Camera, SceneGen, Split};
use p2m::util::bench::Bench;
use p2m::util::rng::Rng;

fn engine(res: usize, fidelity: Fidelity) -> FrontendEngine {
    let cfg = SystemConfig::for_resolution(res);
    let p = cfg.hyper.patch_len();
    let c = cfg.hyper.out_channels;
    let mut rng = Rng::seed(3);
    let theta: Vec<f32> = (0..p * c).map(|_| rng.range(-0.8, 0.8) as f32).collect();
    FrontendEngine::new(
        cfg,
        &theta,
        vec![1.0; c],
        vec![0.5; c],
        TransferSurface::load_default(),
        fidelity,
    )
    .unwrap()
}

fn main() {
    let mut b = Bench::new("frontend");

    let gen = SceneGen::new(80, 5);
    b.run("scene_gen_80", || gen.image(1, 3, Split::Train));

    let scene = gen.image(1, 0, Split::Train);
    let cfg = SensorConfig::default().with_resolution(80);
    let mut rng = Rng::seed(9);
    b.run("photodiode_expose_80", || expose(&cfg, &scene, &mut rng));

    let mut cam = Camera::new(cfg, 1, Split::Train);
    b.run("camera_capture_80 (scene+expose)", || cam.capture());

    let frame = Camera::new(cfg, 2, Split::Train).capture();
    for res in [80usize, 120] {
        let frame = if res == 80 {
            frame.image.clone()
        } else {
            Camera::new(SensorConfig::default().with_resolution(res), 2, Split::Train)
                .capture()
                .image
        };
        let func = engine(res, Fidelity::Functional);
        let n_out = {
            let (ho, wo, c) = func.cfg.out_dims();
            (ho * wo * c) as u64
        };
        b.run_throughput(&format!("frontend_functional_{res}"), n_out, || {
            func.process(&frame)
        });
        // §Perf before/after: the same engine with the folded-polynomial
        // fast path disabled (per-eval reference path).
        let slow = engine(res, Fidelity::Functional).with_fold_disabled();
        b.run_throughput(&format!("frontend_functional_{res}_unfolded"), n_out, || {
            slow.process(&frame)
        });
        let ev = engine(res, Fidelity::EventAccurate);
        b.run_throughput(&format!("frontend_event_{res}"), n_out, || ev.process(&frame));
    }
}
