//! Bench: coordinator substrates (queue, batcher, router), the single-
//! frame frontend at paper scale (GEMM route vs the pre-refactor
//! per-patch folded route, plus row-parallel scheduling), the sharded
//! multi-camera fleet vs sequential single-camera serving, and the full
//! end-to-end PJRT pipeline (the Fig. 8 workload, measured rather than
//! modelled).  The substrate, frontend and fleet rows always run; the
//! PJRT rows need artifacts.
//!
//! Always-run rows are additionally exported as machine-readable
//! `BENCH_pipeline.json` at the repository root (see `util::bench::
//! BenchReport` and `./ci.sh --bench`); keys are machine-independent,
//! so committing the refreshed file records a diffable perf trail
//! across PRs.

use std::path::Path;
use std::time::{Duration, Instant};

use p2m::coordinator::{
    baseline_sensor, default_pool_workers, heterogeneous_fleet_sensors,
    p2m_sensor_from_bundle, run_fleet, run_fleet_pooled, run_pipeline, run_scenario,
    synthetic_fleet_sensors, synthetic_frame_plan, Backpressure, BatchPolicy, Batcher,
    BoundedQueue, CameraScript, CameraSpec, FleetConfig, MeanThresholdClassifier,
    Metrics, PipelineConfig, RoutePolicy, Router, Scenario, WireFormat,
};
use p2m::frontend::Fidelity;
use p2m::model::NativeBackend;
use p2m::runtime::{Manifest, ModelBundle, Runtime};
use p2m::sensor::{SceneGen, Split};
use p2m::util::bench::{bb, Bench, BenchReport};
use p2m::util::simd;

fn main() {
    let mut b = Bench::new("pipeline");
    let mut report = BenchReport::new("pipeline");

    b.run("queue_push_pop", || {
        let q = BoundedQueue::new(64, Backpressure::Block);
        for i in 0..64 {
            q.push(i);
        }
        let mut acc = 0u64;
        while let Some(v) = q.try_pop() {
            acc += v;
        }
        acc
    });

    b.run("batcher_1000_items", || {
        let mut batcher = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
        });
        let mut out = 0usize;
        for i in 0..1000 {
            if let Some(batch) = batcher.push(bb(i), i as f64 * 1e-4) {
                out += batch.len();
            }
        }
        out
    });

    b.run("router_rr_1000", || {
        let mut r = Router::new(4, RoutePolicy::RoundRobin);
        for i in 0..1000 {
            r.enqueue(i % 4, i);
        }
        let mut n = 0;
        while r.next().is_some() {
            n += 1;
        }
        n
    });

    // --- /metrics rendering: the per-scrape cost of the operability
    // plane on a representative registry (the scenario driver's metric
    // population), isolated from any socket I/O.  The render runs on
    // the HTTP thread, never the hot path, but a Prometheus scraper
    // hits it every few seconds for the lifetime of a serve-mode run.
    {
        let metrics = Metrics::new();
        for name in [
            "scenario_frames_captured",
            "scenario_producer_restarts",
            "scheduler_ticks",
            "arena_hits",
            "arena_misses",
            "arena_bytes_recycled",
        ] {
            metrics.counter(name).add(123_456);
        }
        for name in ["scenario_active_cameras", "timer_lag_max_us", "pool_queue_depth"] {
            let g = metrics.gauge(name);
            for i in 0..64 {
                g.observe(i);
            }
        }
        let lat = metrics.latency("scenario_e2e_latency");
        for i in 0..1000 {
            lat.record_secs(1e-4 + (i % 37) as f64 * 1e-5);
        }
        let render_ns = b.run("metrics_render_prometheus", || {
            bb(metrics.render_prometheus().len())
        });
        report.row("metrics_render_prometheus", 1e9 / render_ns, "frames_per_s");
    }

    // --- Single 560x560 frame (paper scale): the §Perf tentpole rows.
    // One shared plan; the GEMM functional route vs the pre-refactor
    // per-patch folded route, and row-block scheduling across all cores.
    {
        let res = 560usize;
        let plan = synthetic_frame_plan(res, Fidelity::Functional).unwrap();
        let per_patch = (*plan).clone().with_gemm_disabled();
        let frame = SceneGen::new(res, 3).image(1, 0, Split::Train);
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

        let mut ctx = plan.ctx();
        let gemm_ns =
            b.run(&format!("frontend_{res}_gemm"), || plan.process(&frame, &mut ctx));
        // The quantized wire sibling: same conversions, payload emitted
        // as raw n_bits ADC codes (steady state: reused ctx + frame).
        let mut qctx = plan.ctx();
        let mut qframe = plan.quantized_frame();
        let quant_ns = b.run(&format!("frontend_{res}_quantized"), || {
            plan.process_quantized_into(&frame, &mut qctx, &mut qframe)
        });
        let mut ctx = per_patch.ctx();
        let prepatch_ns = b.run(&format!("frontend_{res}_per_patch"), || {
            per_patch.process(&frame, &mut ctx)
        });
        let par_ns = b.run(&format!("frontend_{res}_gemm_rows_x{cores}"), || {
            plan.process_parallel(&frame, cores)
        });

        let gemm_speedup = prepatch_ns / gemm_ns;
        let par_speedup = gemm_ns / par_ns;
        println!(
            "{:<44} -> {gemm_speedup:.2}x",
            "gemm_speedup_vs_per_patch_560"
        );
        // The payload-shrink story: measured wire bytes per frame.
        let dense_bytes = (qframe.len() * 4) as f64;
        let quant_bytes = qframe.wire_bytes() as f64;
        println!(
            "{:<44} -> {quant_bytes:.0} B vs {dense_bytes:.0} B dense ({:.2}x shrink)",
            "wire_payload_560",
            dense_bytes / quant_bytes
        );
        // JSON keys are machine-independent (the core count goes in its
        // own row) so committed BENCH_pipeline.json files diff cleanly.
        report.row("frontend_560_gemm", 1e9 / gemm_ns, "frames_per_s");
        report.row("frontend_560_quantized", 1e9 / quant_ns, "frames_per_s");
        report.row("frontend_560_per_patch", 1e9 / prepatch_ns, "frames_per_s");
        report.row("frontend_560_gemm_rows_parallel", 1e9 / par_ns, "frames_per_s");
        report.row("parallel_cores", cores as f64, "count");
        report.row("gemm_speedup_vs_per_patch_560", gemm_speedup, "ratio");
        report.row("row_parallel_speedup_vs_serial_560", par_speedup, "ratio");
        report.row("wire_bytes_dense_560", dense_bytes, "bytes_per_frame");
        report.row("wire_bytes_quantized_560", quant_bytes, "bytes_per_frame");
        report.row("wire_payload_shrink_560", dense_bytes / quant_bytes, "ratio");

        // --- SIMD dispatch-tier rows (DESIGN.md §3.7): the raw kernels
        // behind the rows above, isolated from im2col/quantise/IO. ---
        let tier = simd::active_tier();
        println!("{:<44} -> {tier}", "simd_tier");
        {
            // The frontend's per-frame GEMM shape at paper scale.
            let (m, k, n) = (19_600usize, 450, 16);
            let a: Vec<f64> = (0..m * k).map(|i| (i % 97) as f64 * 1e-2).collect();
            let bm: Vec<f64> = (0..k * n).map(|i| (i % 89) as f64 * 1e-2 - 0.4).collect();
            let mut c = vec![0.0f64; m * n];
            let gemm_simd_ns = b.run("frontend_560_gemm_simd", || {
                simd::matmul_f64(tier, m, k, n, &a, &bm, &mut c);
                bb(c[0])
            });
            report.row("frontend_560_gemm_simd", 1e9 / gemm_simd_ns, "frames_per_s");
        }
        {
            // A native-backend 1x1-conv GEMM tile: dispatched tier vs
            // the scalar reference.  Unit "ratio" so the frames_per_s
            // regression gate never judges it (on SSE2-only hosts the
            // i32 kernel legitimately dispatches to scalar, ratio 1.0).
            let (m, k, n) = (400usize, 64, 128);
            let ai: Vec<i32> = (0..m * k).map(|i| (i % 17) as i32 - 8).collect();
            let bi: Vec<i32> = (0..k * n).map(|i| (i % 255) as i32 - 128).collect();
            let mut ci = vec![0i32; m * n];
            let i32_simd_ns = b.run("native_1x1_gemm_simd", || {
                simd::matmul_i32(tier, m, k, n, &ai, &bi, &mut ci);
                bb(ci[0])
            });
            let i32_scalar_ns = b.run("native_1x1_gemm_scalar", || {
                // The dispatcher zero-fills; the raw scalar kernel
                // accumulates, so match the work (and stay exact).
                ci.fill(0);
                simd::matmul_i32_scalar(m, k, n, &ai, &bi, &mut ci);
                bb(ci[0])
            });
            let ratio = i32_scalar_ns / i32_simd_ns.max(1e-9);
            println!("{:<44} -> {ratio:.2}x", "native_1x1_simd_vs_scalar");
            report.row("native_1x1_simd_vs_scalar", ratio, "ratio");
        }
        {
            // Wire packing of the 560-frame quantized payload through
            // the dispatched bit-packer (qframe was filled above).
            let mut wire = Vec::new();
            let pack_ns = b.run("pack_wire_560", || {
                qframe.pack_wire_into(&mut wire);
                bb(wire.len())
            });
            report.row("pack_wire_throughput", 1e9 / pack_ns, "frames_per_s");
        }
    }

    // --- Fleet vs sequential single-camera: the serving comparison. ---
    // Pure-rust producers + deterministic classifier, so this measures
    // the sharded topology itself and runs in any checkout.  All fleet
    // producers share one compiled FramePlan.
    {
        let cams = 4usize;
        let frames = 24usize;
        let res = 80usize;
        let mk_cfg = |n_cameras: usize, base_seed: u64| FleetConfig {
            n_cameras,
            frames_per_camera: frames,
            batch: 8,
            queue_capacity: 16,
            backpressure: Backpressure::Block,
            base_seed,
            ..FleetConfig::default()
        };
        let metrics = Metrics::new();

        // Warm-up (page in the curve-fit surface etc.).
        let mut clf = MeanThresholdClassifier::new(0.5);
        run_fleet(
            &mut clf,
            synthetic_fleet_sensors(res, Fidelity::Functional, 1, WireFormat::Dense).unwrap(),
            &mk_cfg(1, 99),
            &metrics,
        )
        .unwrap();

        let t0 = Instant::now();
        let mut serial_frames = 0u64;
        for ci in 0..cams {
            let stats = run_fleet(
                &mut clf,
                synthetic_fleet_sensors(res, Fidelity::Functional, 1, WireFormat::Dense)
                    .unwrap(),
                &mk_cfg(1, ci as u64),
                &metrics,
            )
            .unwrap();
            serial_frames += stats.aggregate.frames_classified;
        }
        let serial_s = t0.elapsed().as_secs_f64();
        let serial_fps = serial_frames as f64 / serial_s;

        let t1 = Instant::now();
        let stats = run_fleet(
            &mut clf,
            synthetic_fleet_sensors(res, Fidelity::Functional, cams, WireFormat::Dense)
                .unwrap(),
            &mk_cfg(cams, 0),
            &metrics,
        )
        .unwrap();
        let fleet_s = t1.elapsed().as_secs_f64();
        let fleet_fps = stats.aggregate.frames_classified as f64 / fleet_s;

        // The same fleet on the quantized wire format: identical
        // decisions, 4x fewer link bytes — the throughput effect of
        // emitting codes instead of f32 frames, measured.
        let t2 = Instant::now();
        let qstats = run_fleet(
            &mut clf,
            synthetic_fleet_sensors(res, Fidelity::Functional, cams, WireFormat::Quantized)
                .unwrap(),
            &mk_cfg(cams, 0),
            &metrics,
        )
        .unwrap();
        let qfleet_s = t2.elapsed().as_secs_f64();
        let qfleet_fps = qstats.aggregate.frames_classified as f64 / qfleet_s;

        // Heterogeneous fleet: same camera count and per-camera frame
        // budget, but four cameras across three sensor designs (mixed
        // resolution + bit depth, all quantized wire).  Measures the
        // shape-aware batching + multi-plan serving path against the
        // homogeneous fleet above (not a like-for-like frame workload —
        // smaller sensors are cheaper — but the serving-path overhead
        // shows up in the ratio's trend across PRs).
        let specs = vec![
            CameraSpec::new(0, res, 8, WireFormat::Quantized),
            CameraSpec::new(1, res, 8, WireFormat::Quantized),
            CameraSpec::new(2, 40, 6, WireFormat::Quantized),
            CameraSpec::new(3, 20, 4, WireFormat::Quantized),
        ];
        let (hsensors, bank) = heterogeneous_fleet_sensors(&specs).unwrap();
        let hcfg = FleetConfig { cameras: Some(specs), ..mk_cfg(cams, 0) };
        let t3 = Instant::now();
        let hstats = run_fleet(&mut clf, hsensors, &hcfg, &metrics).unwrap();
        let hfleet_s = t3.elapsed().as_secs_f64();
        let hfleet_fps = hstats.aggregate.frames_classified as f64 / hfleet_s;

        println!(
            "{:<44} -> {serial_fps:.1} frames/s ({serial_frames} frames, {serial_s:.2}s)",
            format!("serving_{cams}x{frames}f_sequential_1cam")
        );
        println!(
            "{:<44} -> {fleet_fps:.1} frames/s ({} frames, {fleet_s:.2}s)",
            format!("serving_{cams}x{frames}f_fleet_{cams}cam"),
            stats.aggregate.frames_classified
        );
        println!(
            "{:<44} -> {qfleet_fps:.1} frames/s ({} B vs {} B on the links)",
            format!("serving_{cams}x{frames}f_fleet_quantized"),
            qstats.aggregate.bytes_from_sensor,
            stats.aggregate.bytes_from_sensor
        );
        println!(
            "{:<44} -> {hfleet_fps:.1} frames/s ({} frames, {} shapes, {} plans)",
            format!("serving_{cams}x{frames}f_fleet_hetero"),
            hstats.aggregate.frames_classified,
            hstats.per_shape.len(),
            bank.len()
        );
        // --- Native integer MobileNetV2 backend + pool scaling. ---
        // The heavy digital-SoC workload (the repo arch's real MAdds per
        // frame) on the quantized wire, served directly and through the
        // BackendPool at 1/2/4 workers: the scaling story the paper's
        // backend-bound serving regime (P2M-DeTrack) needs.
        let run_native = |pool_workers: usize| -> f64 {
            let sensors = synthetic_fleet_sensors(
                res,
                Fidelity::Functional,
                cams,
                WireFormat::Quantized,
            )
            .unwrap();
            let t = Instant::now();
            let stats = if pool_workers <= 1 {
                let mut clf = NativeBackend::new();
                run_fleet(&mut clf, sensors, &mk_cfg(cams, 0), &metrics).unwrap()
            } else {
                run_fleet_pooled(
                    pool_workers,
                    |_| NativeBackend::new(),
                    sensors,
                    &mk_cfg(cams, 0),
                    &metrics,
                )
                .unwrap()
            };
            stats.aggregate.frames_classified as f64 / t.elapsed().as_secs_f64().max(1e-9)
        };
        // Per-worker lazy model compile happens inside the timed window
        // (honest cold-start cost; ~100k RNG draws, negligible against
        // the ~200M MACs of classification per run).
        let native1_fps = run_native(1);
        let native2_fps = run_native(2);
        let native4_fps = run_native(4);
        println!(
            "{:<44} -> {native1_fps:.1} frames/s (direct, 1 worker)",
            format!("serving_{cams}x{frames}f_fleet_native")
        );
        println!(
            "{:<44} -> {native2_fps:.1} / {native4_fps:.1} frames/s (pool x2 / x4), \
             {:.2}x at 4 workers",
            "serving_fleet_native_pool_2_4",
            native4_fps / native1_fps.max(1e-9)
        );
        println!(
            "{:<44} -> {:.2}x",
            "fleet_speedup_vs_sequential",
            fleet_fps / serial_fps
        );
        report.row("serving_sequential_1cam", serial_fps, "frames_per_s");
        report.row("serving_fleet_4cam_native", native1_fps, "frames_per_s");
        report.row("serving_fleet_4cam_native_pool2", native2_fps, "frames_per_s");
        report.row("serving_fleet_4cam_native_pool4", native4_fps, "frames_per_s");
        report.row(
            "native_pool_scaling_4w_vs_1w",
            native4_fps / native1_fps.max(1e-9),
            "ratio",
        );
        report.row("serving_fleet_4cam", fleet_fps, "frames_per_s");
        report.row("serving_fleet_4cam_quantized", qfleet_fps, "frames_per_s");
        report.row("serving_fleet_4cam_hetero", hfleet_fps, "frames_per_s");
        report.row("hetero_vs_homogeneous_fleet", hfleet_fps / fleet_fps.max(1e-9), "ratio");
        report.row("hetero_distinct_plans", bank.len() as f64, "count");
        report.row("hetero_shape_groups", hstats.per_shape.len() as f64, "count");
        report.row("fleet_speedup_vs_sequential", fleet_fps / serial_fps, "ratio");
        report.row(
            "fleet_link_shrink_quantized",
            stats.aggregate.bytes_from_sensor as f64
                / qstats.aggregate.bytes_from_sensor.max(1) as f64,
            "ratio",
        );
    }

    // --- Swarm scale: 100 / 1k / 10k cameras on the fixed producer
    // pool.  Single-shot timed runs (like the serving rows above): the
    // scheduling + routing overhead per frame is what trends here, the
    // per-frame compute is deliberately tiny (20px cameras).
    {
        let pool = default_pool_workers();
        let run_swarm = |n: usize| -> (f64, u64) {
            let scenario = Scenario::swarm(n, 0);
            let metrics = Metrics::new();
            let mut clf = MeanThresholdClassifier::new(0.5);
            let t = Instant::now();
            let r = run_scenario(&mut clf, &scenario, &metrics).unwrap();
            let fps =
                r.aggregate.frames_classified as f64 / t.elapsed().as_secs_f64().max(1e-9);
            (fps, r.aggregate.frames_classified)
        };
        // Warm-up at small scale (plan compile, curve-fit surface).
        run_swarm(16);
        for (key, n) in
            [("swarm_100cam", 100usize), ("swarm_1kcam", 1_000), ("swarm_10kcam", 10_000)]
        {
            let (fps, frames) = run_swarm(n);
            println!("{key:<44} -> {fps:.1} frames/s ({frames} frames, pool {pool})");
            report.row(key, fps, "frames_per_s");
        }
        // A second 1k-camera pass with the process warm: the PR row
        // tracking the arena-recycled producer path end to end (each
        // run builds its own FrameArena, so this is a cold-arena,
        // warm-everything-else serving measurement).
        let (afps, aframes) = run_swarm(1_000);
        println!("{:<44} -> {afps:.1} frames/s ({aframes} frames, pool {pool})", "swarm_1kcam_arena");
        report.row("swarm_1kcam_arena", afps, "frames_per_s");
        // Peak RSS after the 10k-camera run: the memory-ceiling row the
        // fixed pool exists to hold down (state scales with cameras,
        // threads + scratch with workers).  Unit "mb", so the
        // frames_per_s regression gate never judges it — it is a
        // trajectory row, diffable across committed baselines.
        if let Some(mb) = peak_rss_mb() {
            println!("{:<44} -> {mb:.1} MB (VmHWM)", "swarm_peak_rss");
            report.row("swarm_peak_rss", mb, "mb");
        } else {
            println!("{:<44} -> unavailable (no /proc)", "swarm_peak_rss");
        }
    }

    // --- Event wire (Neuromorphic-P2M): the sparse-path rows. ---
    // Frozen scenes are the format's best case and the regression
    // anchor: after the per-camera keyframe every frame is a 4-byte
    // header and the whole frontend recompute is skipped.
    {
        let mut clf = MeanThresholdClassifier::new(0.5);
        let metrics = Metrics::new();
        // 1k frozen 20px event cameras on the fixed pool: the swarm-
        // scale row for the event scheduling + header-only wire path.
        let scripts: Vec<CameraScript> = (0..1_000)
            .map(|id| {
                CameraScript::steady(
                    CameraSpec::new(id, 20, 8, WireFormat::Event).with_freeze(true),
                    8,
                )
            })
            .collect();
        let scenario = Scenario::new("event-1k-static", 0, scripts);
        let t = Instant::now();
        let r = run_scenario(&mut clf, &scenario, &metrics).unwrap();
        let fps = r.aggregate.frames_classified as f64 / t.elapsed().as_secs_f64().max(1e-9);
        println!(
            "{:<44} -> {fps:.1} frames/s ({} frames, {} event bytes)",
            "event_1kcam_static", r.aggregate.frames_classified, r.events.wire_bytes
        );
        report.row("event_1kcam_static", fps, "frames_per_s");

        // Wire-bytes shrink on a static scene at fleet resolution: the
        // exact wire_bits model on both sides (measured event bytes vs
        // the dense code ladder the same frames would have shipped).
        // Gated by the committed "ratio_min" floor of the same name.
        let scripts: Vec<CameraScript> = (0..4)
            .map(|id| {
                CameraScript::steady(
                    CameraSpec::new(id, 80, 8, WireFormat::Event).with_freeze(true),
                    100,
                )
            })
            .collect();
        let scenario = Scenario::new("event-wire-ratio", 0, scripts);
        let r = run_scenario(&mut clf, &scenario, &metrics).unwrap();
        let shrink = r.events.dense_equiv_bytes as f64 / r.events.wire_bytes.max(1) as f64;
        println!(
            "{:<44} -> {shrink:.1}x ({} B vs {} B dense ladder)",
            "event_vs_dense_wire_bytes", r.events.wire_bytes, r.events.dense_equiv_bytes
        );
        report.row("event_vs_dense_wire_bytes", shrink, "ratio");
    }

    // --- Detect workload (P2M-DeTrack): the detection + tracking rows.
    // The canned crash-scripted scenario end to end: stem -> detection
    // head -> per-camera tracker, with the 250 ms SLO armed.  The p99 row
    // is unit "us" (trajectory only, never gated — wall-clock timing);
    // the frames_per_s row rides the regression gate.
    {
        let mut clf = MeanThresholdClassifier::new(0.5);
        let metrics = Metrics::new();
        let scenario = Scenario::canned("detect-track", 0).unwrap();
        // Warm-up (frame-plan + detection-head compile).
        run_scenario(&mut clf, &scenario, &metrics).unwrap();
        let t = Instant::now();
        let r = run_scenario(&mut clf, &scenario, &metrics).unwrap();
        let fps = r.aggregate.frames_classified as f64 / t.elapsed().as_secs_f64().max(1e-9);
        let p99_us = r.aggregate.latency_p99_s * 1e6;
        println!(
            "{:<44} -> {fps:.1} frames/s ({} tracked, {} detections, {} resyncs)",
            "detect_fleet_4cam",
            r.track.frames_tracked,
            r.track.detections,
            r.track.resyncs
        );
        println!("{:<44} -> {p99_us:.0} us (end-to-end p99)", "track_latency_p99_us");
        report.row("detect_fleet_4cam", fps, "frames_per_s");
        report.row("track_latency_p99_us", p99_us, "us");
    }

    // Perf trajectory: machine-readable copy of the always-run rows at
    // the repository root.
    let json_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_pipeline.json");
    match report.write(&json_path) {
        Ok(()) => println!("\nwrote {}", json_path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", json_path.display()),
    }

    // End-to-end pipelines (need artifacts + PJRT).
    if !Manifest::default_dir().join("manifest.json").exists() {
        println!("(skipping end-to-end rows: run `make artifacts`)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let metrics = Metrics::new();

    for (name, batch) in [("e2e_p2m_batch1", 1usize), ("e2e_p2m_batch8", 8)] {
        // Warm the executable cache outside the timed region.
        let cfg = PipelineConfig { n_frames: 8, batch, ..PipelineConfig::default() };
        let sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
        run_pipeline(&mut bundle, sensor, &cfg, &metrics).unwrap();
        let fps = {
            let sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
            run_pipeline(&mut bundle, sensor, &cfg, &metrics).unwrap().throughput_fps
        };
        println!("{name:<44} -> {fps:.1} frames/s (end-to-end)");
    }
    {
        let cfg = PipelineConfig { n_frames: 8, batch: 8, ..PipelineConfig::default() };
        run_pipeline(&mut bundle, baseline_sensor(80), &cfg, &metrics).unwrap();
        let fps = run_pipeline(&mut bundle, baseline_sensor(80), &cfg, &metrics)
            .unwrap()
            .throughput_fps;
        println!("{:<44} -> {fps:.1} frames/s (end-to-end)", "e2e_baseline_batch8");
    }
}

/// Peak resident set (VmHWM) of this process in MiB, from
/// `/proc/self/status`; `None` off Linux.
fn peak_rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb / 1024.0)
}
