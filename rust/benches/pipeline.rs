//! Bench: coordinator substrates (queue, batcher, router), the sharded
//! multi-camera fleet vs sequential single-camera serving, intra-frame
//! row parallelism, and the full end-to-end PJRT pipeline (the Fig. 8
//! workload, measured rather than modelled).  The substrate and fleet
//! rows always run; the PJRT rows need artifacts.

use std::time::{Duration, Instant};

use p2m::coordinator::{
    baseline_sensor, p2m_sensor_from_bundle, run_fleet, run_pipeline,
    synthetic_fleet_sensors, Backpressure, BatchPolicy, Batcher, BoundedQueue,
    FleetConfig, MeanThresholdClassifier, Metrics, PipelineConfig, RoutePolicy, Router,
};
use p2m::frontend::Fidelity;
use p2m::runtime::{Manifest, ModelBundle, Runtime};
use p2m::sensor::{SceneGen, Split};
use p2m::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new("pipeline");

    b.run("queue_push_pop", || {
        let q = BoundedQueue::new(64, Backpressure::Block);
        for i in 0..64 {
            q.push(i);
        }
        let mut acc = 0u64;
        while let Some(v) = q.try_pop() {
            acc += v;
        }
        acc
    });

    b.run("batcher_1000_items", || {
        let mut batcher = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
        });
        let mut out = 0usize;
        for i in 0..1000 {
            if let Some(batch) = batcher.push(bb(i), i as f64 * 1e-4) {
                out += batch.len();
            }
        }
        out
    });

    b.run("router_rr_1000", || {
        let mut r = Router::new(4, RoutePolicy::RoundRobin);
        for i in 0..1000 {
            r.enqueue(i % 4, i);
        }
        let mut n = 0;
        while r.next().is_some() {
            n += 1;
        }
        n
    });

    // --- Intra-frame row parallelism: one 560x560 frame, all cores. ---
    {
        let res = 560usize;
        let sensors = synthetic_fleet_sensors(res, Fidelity::Functional, 1).unwrap();
        let p2m::coordinator::SensorCompute::P2m(engine) = &sensors[0] else {
            unreachable!()
        };
        let frame = SceneGen::new(res, 3).image(1, 0, Split::Train);
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        b.run(&format!("frontend_{res}_rows_serial"), || engine.process(&frame));
        b.run(&format!("frontend_{res}_rows_x{cores}"), || {
            engine.process_parallel(&frame, cores)
        });
    }

    // --- Fleet vs sequential single-camera: the tentpole comparison. ---
    // Pure-rust producers + deterministic classifier, so this measures
    // the sharded topology itself and runs in any checkout.
    {
        let cams = 4usize;
        let frames = 24usize;
        let res = 80usize;
        let mk_cfg = |n_cameras: usize, base_seed: u64| FleetConfig {
            n_cameras,
            frames_per_camera: frames,
            batch: 8,
            queue_capacity: 16,
            backpressure: Backpressure::Block,
            base_seed,
            ..FleetConfig::default()
        };
        let metrics = Metrics::new();

        // Warm-up (page in the curve-fit surface etc.).
        let mut clf = MeanThresholdClassifier::new(0.5);
        run_fleet(
            &mut clf,
            synthetic_fleet_sensors(res, Fidelity::Functional, 1).unwrap(),
            &mk_cfg(1, 99),
            &metrics,
        )
        .unwrap();

        let t0 = Instant::now();
        let mut serial_frames = 0u64;
        for ci in 0..cams {
            let stats = run_fleet(
                &mut clf,
                synthetic_fleet_sensors(res, Fidelity::Functional, 1).unwrap(),
                &mk_cfg(1, ci as u64),
                &metrics,
            )
            .unwrap();
            serial_frames += stats.aggregate.frames_classified;
        }
        let serial_s = t0.elapsed().as_secs_f64();
        let serial_fps = serial_frames as f64 / serial_s;

        let t1 = Instant::now();
        let stats = run_fleet(
            &mut clf,
            synthetic_fleet_sensors(res, Fidelity::Functional, cams).unwrap(),
            &mk_cfg(cams, 0),
            &metrics,
        )
        .unwrap();
        let fleet_s = t1.elapsed().as_secs_f64();
        let fleet_fps = stats.aggregate.frames_classified as f64 / fleet_s;

        println!(
            "{:<44} -> {serial_fps:.1} frames/s ({serial_frames} frames, {serial_s:.2}s)",
            format!("serving_{cams}x{frames}f_sequential_1cam")
        );
        println!(
            "{:<44} -> {fleet_fps:.1} frames/s ({} frames, {fleet_s:.2}s)",
            format!("serving_{cams}x{frames}f_fleet_{cams}cam"),
            stats.aggregate.frames_classified
        );
        println!(
            "{:<44} -> {:.2}x",
            "fleet_speedup_vs_sequential",
            fleet_fps / serial_fps
        );
    }

    // End-to-end pipelines (need artifacts + PJRT).
    if !Manifest::default_dir().join("manifest.json").exists() {
        println!("(skipping end-to-end rows: run `make artifacts`)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let metrics = Metrics::new();

    for (name, batch) in [("e2e_p2m_batch1", 1usize), ("e2e_p2m_batch8", 8)] {
        // Warm the executable cache outside the timed region.
        let cfg = PipelineConfig { n_frames: 8, batch, ..PipelineConfig::default() };
        let sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
        run_pipeline(&mut bundle, sensor, &cfg, &metrics).unwrap();
        let fps = {
            let sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
            run_pipeline(&mut bundle, sensor, &cfg, &metrics).unwrap().throughput_fps
        };
        println!("{name:<44} -> {fps:.1} frames/s (end-to-end)");
    }
    {
        let cfg = PipelineConfig { n_frames: 8, batch: 8, ..PipelineConfig::default() };
        run_pipeline(&mut bundle, baseline_sensor(80), &cfg, &metrics).unwrap();
        let fps = run_pipeline(&mut bundle, baseline_sensor(80), &cfg, &metrics)
            .unwrap()
            .throughput_fps;
        println!("{:<44} -> {fps:.1} frames/s (end-to-end)", "e2e_baseline_batch8");
    }
}
