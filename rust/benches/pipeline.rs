//! Bench: coordinator substrates (queue, batcher, router) and the full
//! end-to-end serving pipeline (the Fig. 8 workload, measured rather
//! than modelled).  Requires artifacts for the end-to-end rows; the
//! substrate rows always run.

use std::time::Duration;

use p2m::coordinator::{
    baseline_sensor, p2m_sensor_from_bundle, run_pipeline, Backpressure, BatchPolicy,
    Batcher, BoundedQueue, Metrics, PipelineConfig, RoutePolicy, Router,
};
use p2m::frontend::Fidelity;
use p2m::runtime::{Manifest, ModelBundle, Runtime};
use p2m::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new("pipeline");

    b.run("queue_push_pop", || {
        let q = BoundedQueue::new(64, Backpressure::Block);
        for i in 0..64 {
            q.push(i);
        }
        let mut acc = 0u64;
        while let Some(v) = q.try_pop() {
            acc += v;
        }
        acc
    });

    b.run("batcher_1000_items", || {
        let mut batcher = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
        });
        let mut out = 0usize;
        for i in 0..1000 {
            if let Some(batch) = batcher.push(bb(i), i as f64 * 1e-4) {
                out += batch.len();
            }
        }
        out
    });

    b.run("router_rr_1000", || {
        let mut r = Router::new(4, RoutePolicy::RoundRobin);
        for i in 0..1000 {
            r.enqueue(i % 4, i);
        }
        let mut n = 0;
        while r.next().is_some() {
            n += 1;
        }
        n
    });

    // End-to-end pipelines (need artifacts + PJRT).
    if !Manifest::default_dir().join("manifest.json").exists() {
        println!("(skipping end-to-end rows: run `make artifacts`)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut bundle = ModelBundle::load(&rt, 80).unwrap();
    let metrics = Metrics::new();

    for (name, batch) in [("e2e_p2m_batch1", 1usize), ("e2e_p2m_batch8", 8)] {
        // Warm the executable cache outside the timed region.
        let cfg = PipelineConfig { n_frames: 8, batch, ..PipelineConfig::default() };
        let sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
        run_pipeline(&mut bundle, sensor, &cfg, &metrics).unwrap();
        let fps = {
            let sensor = p2m_sensor_from_bundle(&bundle, Fidelity::Functional).unwrap();
            run_pipeline(&mut bundle, sensor, &cfg, &metrics).unwrap().throughput_fps
        };
        println!("{name:<44} -> {fps:.1} frames/s (end-to-end)");
    }
    {
        let cfg = PipelineConfig { n_frames: 8, batch: 8, ..PipelineConfig::default() };
        run_pipeline(&mut bundle, baseline_sensor(80), &cfg, &metrics).unwrap();
        let fps = run_pipeline(&mut bundle, baseline_sensor(80), &cfg, &metrics)
            .unwrap()
            .throughput_fps;
        println!("{:<44} -> {fps:.1} frames/s (end-to-end)", "e2e_baseline_batch8");
    }
}
