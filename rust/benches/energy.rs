//! Bench: the Eq. 4-8 energy/delay/EDP model and the Table 2 analytics —
//! these run inside every CLI table command and the design-space sweep,
//! so they should be effectively free.

use p2m::compression;
use p2m::config::HyperParams;
use p2m::energy::{DelayConstants, EnergyConstants, PipelineKind, PipelineModel};
use p2m::model::{analyse, table2_rows, ArchConfig};
use p2m::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new("energy+model");

    let e = EnergyConstants::default();
    let d = DelayConstants::default();
    let p2m = PipelineModel::from_paper_reported(PipelineKind::P2m);
    b.run("energy_eq4", || p2m.energy(&e).total());
    b.run("delay_eq7_aggregate", || p2m.delay(&d).total_sequential());

    let arch = ArchConfig::paper_baseline(560);
    let per_layer = PipelineModel::from_arch(PipelineKind::BaselineCompressed, &arch);
    b.run("delay_eq7_per_layer (46 layers)", || per_layer.t_conv(&d));
    b.run("edp_pair", || {
        bb(p2m.edp(&e, &d, true)) + per_layer.edp(&e, &d, false)
    });

    b.run("arch_expand_paper_baseline", || arch.layers());
    b.run("model_analyse_560", || analyse(&arch));
    b.run("table2_all_rows", table2_rows);

    let h = HyperParams::default();
    b.run("bandwidth_reduction_eq2", || {
        compression::bandwidth_reduction(&h, bb(560), 12)
    });
    b.run("tech_scaling_45to22", || {
        p2m::energy::scale_energy(bb(3.1e-12), 45, 22).unwrap()
    });
}
